//! RTEN: a trivially simple binary tensor container for checkpoints.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic  "RTEN1\0\0\0"                      (8 bytes)
//! u32    n_entries
//! per entry:
//!   u32  name_len, name bytes (utf-8)
//!   u8   dtype (0 = f32, 1 = i32, 2 = u8, 3 = bf16)
//!   u32  rank, u64 dims[rank]
//!   raw  data (dims product * dtype size bytes)
//! ```
//!
//! dtype 2 (u8) carries the 8-bit quantized optimizer-state codes of
//! checkpoint v2 (`docs/checkpoint-v2.md`); dtype 3 (bf16, raw u16
//! bit patterns, little-endian) carries the stochastic-rounding weight
//! planes. Readers predating either reject the entry's dtype byte
//! loudly instead of misparsing the stream.
//!
//! No compression — checkpoints are local scratch, and `write_atomic`
//! protects against torn files.
//!
//! Integrity: writers append an 8-byte footer `"CRC1"` + CRC-32 (IEEE,
//! little-endian) of every preceding byte. Readers verify the checksum
//! when the footer is present and still accept footer-less files written
//! by older builds. A checksum mismatch is a hard error — a torn or
//! bit-flipped checkpoint must never be silently resumed from
//! (`docs/checkpoint-v2.md`).

use std::collections::BTreeMap;
use std::io::{Cursor, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Tensor, TensorBf16, TensorU8};
use crate::util::fsutil;

const MAGIC: &[u8; 8] = b"RTEN1\0\0\0";
const FOOTER_MAGIC: &[u8; 4] = b"CRC1";

/// One stored tensor — f32 (parameters, moments, scales), raw u8
/// (quantized codes) or bf16 (stochastic-rounding weight planes).
#[derive(Debug, Clone, PartialEq)]
pub enum RtenEntry {
    F32(Tensor),
    U8(TensorU8),
    Bf16(TensorBf16),
}

impl RtenEntry {
    fn dtype(&self) -> u8 {
        match self {
            RtenEntry::F32(_) => 0,
            RtenEntry::U8(_) => 2,
            RtenEntry::Bf16(_) => 3,
        }
    }

    fn shape(&self) -> &[usize] {
        match self {
            RtenEntry::F32(t) => &t.shape,
            RtenEntry::U8(t) => &t.shape,
            RtenEntry::Bf16(t) => &t.shape,
        }
    }
}

/// Serialize one entry header + payload; shared by both writers so the
/// all-f32 path never has to materialize an owned `RtenEntry` map.
fn write_entry(
    buf: &mut Vec<u8>,
    name: &str,
    dtype: u8,
    shape: &[usize],
    payload: &mut dyn FnMut(&mut Vec<u8>) -> Result<()>,
) -> Result<()> {
    buf.write_all(&(name.len() as u32).to_le_bytes())?;
    buf.write_all(name.as_bytes())?;
    buf.push(dtype);
    buf.write_all(&(shape.len() as u32).to_le_bytes())?;
    for d in shape {
        buf.write_all(&(*d as u64).to_le_bytes())?;
    }
    payload(buf)
}

/// Append the integrity footer: `"CRC1"` + CRC-32 of everything before it.
fn push_footer(buf: &mut Vec<u8>) {
    let crc = fsutil::crc32(buf);
    buf.extend_from_slice(FOOTER_MAGIC);
    buf.extend_from_slice(&crc.to_le_bytes());
}

/// Validate and strip the `"CRC1"` footer when present; files written
/// before the footer existed pass through unchanged.
fn verify_footer<'a>(bytes: &'a [u8], path: &Path) -> Result<&'a [u8]> {
    let n = bytes.len();
    if n < MAGIC.len() + 8 || &bytes[n - 8..n - 4] != FOOTER_MAGIC {
        return Ok(bytes);
    }
    let payload = &bytes[..n - 8];
    let stored = u32::from_le_bytes([bytes[n - 4], bytes[n - 3], bytes[n - 2], bytes[n - 1]]);
    let computed = fsutil::crc32(payload);
    if stored != computed {
        bail!(
            "{}: CRC-32 mismatch (footer {stored:08x}, payload {computed:08x}) — \
             torn or corrupt file",
            path.display()
        );
    }
    Ok(payload)
}

/// Serialize a mixed f32/u8 tensor map to RTEN bytes (footer included).
pub fn rten_entry_bytes(entries: &BTreeMap<String, RtenEntry>) -> Result<Vec<u8>> {
    let mut buf: Vec<u8> = Vec::new();
    buf.write_all(MAGIC)?;
    buf.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, e) in entries {
        write_entry(&mut buf, name, e.dtype(), e.shape(), &mut |buf| {
            match e {
                RtenEntry::F32(t) => {
                    for x in &t.data {
                        buf.write_all(&x.to_le_bytes())?;
                    }
                }
                RtenEntry::U8(t) => buf.write_all(&t.data)?,
                RtenEntry::Bf16(t) => {
                    for x in &t.data {
                        buf.write_all(&x.to_le_bytes())?;
                    }
                }
            }
            Ok(())
        })?;
    }
    push_footer(&mut buf);
    Ok(buf)
}

/// Write a mixed f32/u8 tensor map.
pub fn write_rten_entries(path: &Path, entries: &BTreeMap<String, RtenEntry>) -> Result<()> {
    fsutil::write_atomic(path, &rten_entry_bytes(entries)?)
}

/// Read a mixed f32/u8 tensor map.
pub fn read_rten_entries(path: &Path) -> Result<BTreeMap<String, RtenEntry>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let payload = verify_footer(&bytes, path)?;
    let mut cur = Cursor::new(payload);
    let mut magic = [0u8; 8];
    cur.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an RTEN file", path.display());
    }
    let n = read_u32(&mut cur)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u32(&mut cur)? as usize;
        let mut name = vec![0u8; name_len];
        cur.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name is not utf-8")?;
        let mut dtype = [0u8; 1];
        cur.read_exact(&mut dtype)?;
        let rank = read_u32(&mut cur)? as usize;
        if rank > 8 {
            bail!("implausible rank {rank} for '{name}'");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut d = [0u8; 8];
            cur.read_exact(&mut d)?;
            shape.push(u64::from_le_bytes(d) as usize);
        }
        let count: usize = shape.iter().product();
        let entry = match dtype[0] {
            0 => {
                let mut data = vec![0f32; count];
                for x in data.iter_mut() {
                    let mut b = [0u8; 4];
                    cur.read_exact(&mut b)?;
                    *x = f32::from_le_bytes(b);
                }
                RtenEntry::F32(Tensor { shape, data })
            }
            2 => {
                let mut data = vec![0u8; count];
                cur.read_exact(&mut data)?;
                RtenEntry::U8(TensorU8 { shape, data })
            }
            3 => {
                let mut data = vec![0u16; count];
                for x in data.iter_mut() {
                    let mut b = [0u8; 2];
                    cur.read_exact(&mut b)?;
                    *x = u16::from_le_bytes(b);
                }
                RtenEntry::Bf16(TensorBf16 { shape, data })
            }
            other => bail!("unsupported dtype {other} for '{name}'"),
        };
        out.insert(name, entry);
    }
    Ok(out)
}

/// Serialize an all-f32 tensor map to RTEN bytes (footer included) —
/// straight from the borrowed map, no owned copy.
pub fn rten_bytes(tensors: &BTreeMap<String, Tensor>) -> Result<Vec<u8>> {
    let mut buf: Vec<u8> = Vec::new();
    buf.write_all(MAGIC)?;
    buf.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        write_entry(&mut buf, name, 0, &t.shape, &mut |buf| {
            for x in &t.data {
                buf.write_all(&x.to_le_bytes())?;
            }
            Ok(())
        })?;
    }
    push_footer(&mut buf);
    Ok(buf)
}

/// All-f32 convenience writer (parameters, v1 checkpoints).
pub fn write_rten(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    fsutil::write_atomic(path, &rten_bytes(tensors)?)
}

/// All-f32 convenience reader — errors if the file holds a u8 entry.
pub fn read_rten(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut out = BTreeMap::new();
    for (name, e) in read_rten_entries(path)? {
        match e {
            RtenEntry::F32(t) => {
                out.insert(name, t);
            }
            RtenEntry::U8(_) | RtenEntry::Bf16(_) => bail!(
                "'{name}' in {} is not an f32 tensor; this reader only handles f32 maps \
                 (use read_rten_entries)",
                path.display()
            ),
        }
    }
    Ok(out)
}

fn read_u32(cur: &mut Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
        m.insert("b".to_string(), Tensor::new(vec![3], vec![-1., 0., 1.]).unwrap());
        m.insert("s".to_string(), Tensor::scalar(7.5));
        let path = std::env::temp_dir().join(format!("rten_{}.bin", std::process::id()));
        write_rten(&path, &m).unwrap();
        let back = read_rten(&path).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mixed_u8_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert(
            "w/mq_sc".to_string(),
            RtenEntry::F32(Tensor::new(vec![2], vec![0.5, 0.25]).unwrap()),
        );
        m.insert(
            "w/mq_q8".to_string(),
            RtenEntry::U8(TensorU8::new(vec![2, 3], vec![0, 127, 255, 1, 2, 3]).unwrap()),
        );
        m.insert(
            "w/bf16".to_string(),
            RtenEntry::Bf16(TensorBf16::new(vec![2, 2], vec![0x3f80, 0xbf80, 0x0000, 0x4000]).unwrap()),
        );
        let path = std::env::temp_dir().join(format!("rten_u8_{}.bin", std::process::id()));
        write_rten_entries(&path, &m).unwrap();
        let back = read_rten_entries(&path).unwrap();
        assert_eq!(back, m);
        // the all-f32 reader refuses the u8 entry instead of misreading it
        assert!(read_rten(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn checksum_footer_catches_corruption() {
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap());
        let path = std::env::temp_dir().join(format!("rten_crc_{}.bin", std::process::id()));
        write_rten(&path, &m).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        assert_eq!(&bytes[n - 8..n - 4], FOOTER_MAGIC, "writer must append the footer");
        // flip one payload bit: the reader must refuse the file
        bytes[MAGIC.len() + 5] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_rten(&path).unwrap_err();
        assert!(format!("{err:#}").contains("CRC-32 mismatch"), "{err:#}");
        // a footer-less (legacy) file still parses
        let legacy = rten_bytes(&m).unwrap();
        std::fs::write(&path, &legacy[..legacy.len() - 8]).unwrap();
        assert_eq!(read_rten(&path).unwrap(), m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = std::env::temp_dir().join(format!("rten_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"NOTRTEN0rest").unwrap();
        assert!(read_rten(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
