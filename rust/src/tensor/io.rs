//! RTEN: a trivially simple binary tensor container for checkpoints.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic  "RTEN1\0\0\0"                      (8 bytes)
//! u32    n_entries
//! per entry:
//!   u32  name_len, name bytes (utf-8)
//!   u8   dtype (0 = f32, 1 = i32)
//!   u32  rank, u64 dims[rank]
//!   raw  data (dims product * 4 bytes)
//! ```
//!
//! No compression — checkpoints are local scratch, and `write_atomic`
//! protects against torn files.

use std::collections::BTreeMap;
use std::io::{Cursor, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Tensor;
use crate::util::fsutil;

const MAGIC: &[u8; 8] = b"RTEN1\0\0\0";

pub fn write_rten(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.write_all(MAGIC)?;
    buf.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        buf.write_all(&(name.len() as u32).to_le_bytes())?;
        buf.write_all(name.as_bytes())?;
        buf.push(0u8); // dtype f32
        buf.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for d in &t.shape {
            buf.write_all(&(*d as u64).to_le_bytes())?;
        }
        for x in &t.data {
            buf.write_all(&x.to_le_bytes())?;
        }
    }
    fsutil::write_atomic(path, &buf)
}

pub fn read_rten(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut cur = Cursor::new(bytes.as_slice());
    let mut magic = [0u8; 8];
    cur.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an RTEN file", path.display());
    }
    let n = read_u32(&mut cur)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u32(&mut cur)? as usize;
        let mut name = vec![0u8; name_len];
        cur.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name is not utf-8")?;
        let mut dtype = [0u8; 1];
        cur.read_exact(&mut dtype)?;
        if dtype[0] != 0 {
            bail!("unsupported dtype {} for '{name}'", dtype[0]);
        }
        let rank = read_u32(&mut cur)? as usize;
        if rank > 8 {
            bail!("implausible rank {rank} for '{name}'");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut d = [0u8; 8];
            cur.read_exact(&mut d)?;
            shape.push(u64::from_le_bytes(d) as usize);
        }
        let count: usize = shape.iter().product();
        let mut data = vec![0f32; count];
        for x in data.iter_mut() {
            let mut b = [0u8; 4];
            cur.read_exact(&mut b)?;
            *x = f32::from_le_bytes(b);
        }
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

fn read_u32(cur: &mut Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
        m.insert("b".to_string(), Tensor::new(vec![3], vec![-1., 0., 1.]).unwrap());
        m.insert("s".to_string(), Tensor::scalar(7.5));
        let path = std::env::temp_dir().join(format!("rten_{}.bin", std::process::id()));
        write_rten(&path, &m).unwrap();
        let back = read_rten(&path).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = std::env::temp_dir().join(format!("rten_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"NOTRTEN0rest").unwrap();
        assert!(read_rten(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
