//! Row-major host tensors (f32 and i32).
//!
//! Deliberately minimal: the heavy math runs inside XLA; the host side only
//! needs construction, elementwise helpers for cross-validation, and the
//! spectral probe (which uses `linalg`).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

/// Raw byte tensor — the storage type of 8-bit quantized optimizer state
/// (`optim::quant`). Carries no scale information itself; quantization
/// metadata lives with the owner (`QTensor`).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorU8 {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl TensorU8 {
    pub fn new(shape: Vec<usize>, data: Vec<u8>) -> Result<TensorU8> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(TensorU8 { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> TensorU8 {
        TensorU8 { shape: shape.to_vec(), data: vec![0u8; shape.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// One byte per element.
    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }
}

/// bf16 plane — the storage type of the stochastic-rounding weight layout
/// (`optim::bf16`). Elements are raw bf16 bit patterns (the upper 16 bits of
/// the equivalent f32); conversion helpers live in `optim::bf16` so the
/// tensor layer stays arithmetic-free.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBf16 {
    pub shape: Vec<usize>,
    pub data: Vec<u16>,
}

impl TensorBf16 {
    pub fn new(shape: Vec<usize>, data: Vec<u16>) -> Result<TensorBf16> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(TensorBf16 { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> TensorBf16 {
        TensorBf16 { shape: shape.to_vec(), data: vec![0u16; shape.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Two bytes per element.
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 2
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// (rows, cols) view of a 2-D tensor.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [m, n] => Ok((*m, *n)),
            s => bail!("expected 2-D tensor, got shape {s:?}"),
        }
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, n) = (self.shape[0], self.shape[1]);
        self.data[i * n + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let n = self.shape[1];
        self.data[i * n + j] = v;
    }

    pub fn norm_fro(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn norm_l11(&self) -> f32 {
        self.data.iter().map(|x| x.abs() as f64).sum::<f64>() as f32
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Relative Frobenius error ||a-b|| / max(||b||, eps).
    pub fn rel_err(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num.sqrt() / den.sqrt().max(1e-12)) as f32
    }

    pub fn transpose2(&self) -> Result<Tensor> {
        let (m, n) = self.dims2()?;
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// In-place axpy: self = alpha * x + beta * self.
    pub fn axpy(&mut self, alpha: f32, x: &Tensor, beta: f32) {
        assert_eq!(self.shape, x.shape);
        for (s, v) in self.data.iter_mut().zip(&x.data) {
            *s = alpha * v + beta * *s;
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|x| f(*x)).collect() }
    }
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<TensorI32> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(TensorI32 { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> TensorI32 {
        TensorI32 { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn scalar(v: i32) -> TensorI32 {
        TensorI32 { shape: vec![], data: vec![v] }
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(TensorI32::new(vec![4], vec![1, 2, 3]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        let tt = t.transpose2().unwrap();
        assert_eq!(tt.shape, vec![3, 2]);
        assert_eq!(tt.at2(2, 1), t.at2(1, 2));
        assert_eq!(tt.transpose2().unwrap(), t);
    }

    #[test]
    fn norms() {
        let t = Tensor::new(vec![2, 2], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert!((t.norm_fro() - 5.0).abs() < 1e-6);
        assert!((t.norm_l11() - 7.0).abs() < 1e-6);
    }

    #[test]
    fn rel_err_and_axpy() {
        let a = Tensor::full(&[4], 1.0);
        let mut b = Tensor::full(&[4], 2.0);
        assert!((a.rel_err(&b) - 0.5).abs() < 1e-6);
        b.axpy(1.0, &a, -1.0); // b = a - b = -1
        assert_eq!(b.data, vec![-1.0; 4]);
    }

    #[test]
    fn scalar_tensor() {
        let s = Tensor::scalar(2.5);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.len(), 1);
    }
}
