//! Host tensors: the coordinator-side representation of parameters,
//! gradients and optimizer state between PJRT calls.

mod io;
mod tensor;

pub use io::{read_rten, write_rten};
pub use tensor::{Tensor, TensorI32};
