//! Host tensors: the coordinator-side representation of parameters,
//! gradients and optimizer state between PJRT calls.

mod io;
mod tensor;

pub use io::{
    read_rten, read_rten_entries, rten_bytes, rten_entry_bytes, write_rten, write_rten_entries,
    RtenEntry,
};
pub use tensor::{Tensor, TensorBf16, TensorI32, TensorU8};
