//! Host values crossing the PJRT boundary.

use anyhow::{bail, Result};

use crate::tensor::{Tensor, TensorI32};

#[derive(Debug, Clone, PartialEq)]
pub enum HostValue {
    F32(Tensor),
    I32(TensorI32),
}

impl HostValue {
    pub fn scalar_f32(x: f32) -> HostValue {
        HostValue::F32(Tensor::scalar(x))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => &t.shape,
            HostValue::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostValue::F32(_) => "float32",
            HostValue::I32(_) => "int32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            HostValue::F32(t) => t.size_bytes(),
            HostValue::I32(t) => t.size_bytes(),
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            HostValue::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            HostValue::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI32> {
        match self {
            HostValue::I32(t) => Ok(t),
            HostValue::F32(_) => bail!("expected i32 value, got f32"),
        }
    }

    /// Scalar extraction (rank-0 f32).
    pub fn scalar(&self) -> Result<f32> {
        let t = self.as_f32()?;
        if t.len() != 1 {
            bail!("expected scalar, got shape {:?}", t.shape);
        }
        Ok(t.data[0])
    }
}

/// Borrowed view for zero-clone graph invocation (the hot path passes
/// parameter tensors by reference every step).
#[derive(Debug, Clone, Copy)]
pub enum ValRef<'a> {
    F32(&'a Tensor),
    I32(&'a TensorI32),
}

impl<'a> ValRef<'a> {
    pub fn shape(&self) -> &[usize] {
        match self {
            ValRef::F32(t) => &t.shape,
            ValRef::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            ValRef::F32(_) => "float32",
            ValRef::I32(_) => "int32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            ValRef::F32(t) => t.size_bytes(),
            ValRef::I32(t) => t.size_bytes(),
        }
    }
}

impl<'a> From<&'a HostValue> for ValRef<'a> {
    fn from(v: &'a HostValue) -> ValRef<'a> {
        match v {
            HostValue::F32(t) => ValRef::F32(t),
            HostValue::I32(t) => ValRef::I32(t),
        }
    }
}

impl<'a> From<&'a Tensor> for ValRef<'a> {
    fn from(t: &'a Tensor) -> ValRef<'a> {
        ValRef::F32(t)
    }
}

impl<'a> From<&'a TensorI32> for ValRef<'a> {
    fn from(t: &'a TensorI32) -> ValRef<'a> {
        ValRef::I32(t)
    }
}

impl From<Tensor> for HostValue {
    fn from(t: Tensor) -> Self {
        HostValue::F32(t)
    }
}

impl From<TensorI32> for HostValue {
    fn from(t: TensorI32) -> Self {
        HostValue::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = HostValue::scalar_f32(2.0);
        assert_eq!(v.scalar().unwrap(), 2.0);
        assert_eq!(v.dtype(), "float32");
        assert!(v.as_i32().is_err());
        let t: HostValue = TensorI32::zeros(&[2, 3]).into();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.size_bytes(), 24);
        assert!(t.scalar().is_err());
    }
}
