//! Layer-3 runtime: loads AOT artifacts (HLO text + manifest) and executes
//! them on the PJRT CPU client.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Interchange is HLO *text*; see aot.py.

mod client;
mod manifest;
mod value;

pub use client::{LoadedGraph, Runtime};
pub use manifest::{GraphSpec, IoSpec, Manifest, ModelDims, ParamSpec, Preset};
pub use value::{HostValue, ValRef};
