//! PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! Executables are cached per file path; inputs/outputs are checked against
//! the manifest IO tables so a drifted artifact fails loudly at the
//! boundary instead of producing garbage.
//!
//! The actual PJRT backend needs the external `xla` bindings crate, which
//! the hermetic offline build does not carry. It is therefore gated behind
//! the `pjrt` cargo feature; the default build ships a stub `Runtime` with
//! the same API whose constructor fails with a clear message. Everything
//! that gates on `make artifacts` being present (trainer smoke tests,
//! cross-validation, graph benches) skips cleanly in stub builds, while
//! the pure-host path (linalg kernels, reference optimizers, host benches)
//! is fully functional.

#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    pub bytes_in: usize,
    pub bytes_out: usize,
}

#[cfg(feature = "pjrt")]
mod backend {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;
    use std::time::Instant;

    use anyhow::{anyhow, bail, Result};

    use crate::tensor::{Tensor, TensorI32};

    use super::super::manifest::GraphSpec;
    use super::super::value::{HostValue, ValRef};
    use super::RuntimeStats;

    pub struct Runtime {
        client: xla::PjRtClient,
        root: PathBuf,
        cache: RefCell<HashMap<String, Rc<LoadedGraph>>>,
        /// cumulative executor statistics (perf accounting)
        pub stats: RefCell<RuntimeStats>,
    }

    pub struct LoadedGraph {
        pub spec: GraphSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Runtime {
        /// CPU PJRT client rooted at the artifacts directory.
        pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            log::debug!(
                "PJRT platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            Ok(Runtime {
                client,
                root: artifacts_dir.to_path_buf(),
                cache: RefCell::new(HashMap::new()),
                stats: RefCell::new(RuntimeStats::default()),
            })
        }

        /// Load + compile (cached) the graph described by `spec`.
        pub fn load(&self, spec: &GraphSpec) -> Result<Rc<LoadedGraph>> {
            if let Some(g) = self.cache.borrow().get(&spec.file) {
                return Ok(g.clone());
            }
            let path = self.root.join(&spec.file);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            let dt = t0.elapsed().as_secs_f64();
            {
                let mut s = self.stats.borrow_mut();
                s.compiles += 1;
                s.compile_secs += dt;
            }
            log::debug!("compiled {} in {:.2}s", spec.file, dt);
            let g = Rc::new(LoadedGraph { spec: spec.clone(), exe });
            self.cache.borrow_mut().insert(spec.file.clone(), g.clone());
            Ok(g)
        }

        /// Execute a loaded graph on host values, returning host values in the
        /// graph's output order.
        pub fn execute(&self, g: &LoadedGraph, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
            let refs: Vec<ValRef> = inputs.iter().map(ValRef::from).collect();
            self.execute_refs(g, &refs)
        }

        /// Zero-clone execution path: borrows the input tensors (the training
        /// hot loop passes parameters by reference every step).
        pub fn execute_refs(&self, g: &LoadedGraph, inputs: &[ValRef]) -> Result<Vec<HostValue>> {
            self.check_inputs(g, inputs)?;
            let literals = inputs.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
            let t0 = Instant::now();
            let result = g
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {}: {e:?}", g.spec.file))?;
            let out_lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {}: {e:?}", g.spec.file))?;
            // aot.py lowers with return_tuple=True: root is always a tuple.
            let parts = out_lit
                .to_tuple()
                .map_err(|e| anyhow!("untupling result of {}: {e:?}", g.spec.file))?;
            if parts.len() != g.spec.outputs.len() {
                bail!(
                    "{}: expected {} outputs, got {}",
                    g.spec.file,
                    g.spec.outputs.len(),
                    parts.len()
                );
            }
            let out = parts.into_iter().map(from_literal).collect::<Result<Vec<_>>>()?;
            let dt = t0.elapsed().as_secs_f64();
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_secs += dt;
            s.bytes_in += inputs.iter().map(|v| v.size_bytes()).sum::<usize>();
            s.bytes_out += out.iter().map(|v| v.size_bytes()).sum::<usize>();
            Ok(out)
        }

        /// Convenience: load + execute in one call.
        pub fn run(&self, spec: &GraphSpec, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
            let g = self.load(spec)?;
            self.execute(&g, inputs)
        }

        /// Convenience: load + execute by reference.
        pub fn run_refs(&self, spec: &GraphSpec, inputs: &[ValRef]) -> Result<Vec<HostValue>> {
            let g = self.load(spec)?;
            self.execute_refs(&g, inputs)
        }

        fn check_inputs(&self, g: &LoadedGraph, inputs: &[ValRef]) -> Result<()> {
            if inputs.len() != g.spec.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    g.spec.file,
                    g.spec.inputs.len(),
                    inputs.len()
                );
            }
            for (io, v) in g.spec.inputs.iter().zip(inputs) {
                if io.shape != v.shape() {
                    bail!(
                        "{}: input '{}' expects shape {:?}, got {:?}",
                        g.spec.file,
                        io.name,
                        io.shape,
                        v.shape()
                    );
                }
                if io.dtype != v.dtype() {
                    bail!(
                        "{}: input '{}' expects dtype {}, got {}",
                        g.spec.file,
                        io.name,
                        io.dtype,
                        v.dtype()
                    );
                }
            }
            Ok(())
        }

        pub fn stats_snapshot(&self) -> RuntimeStats {
            self.stats.borrow().clone()
        }
    }

    fn to_literal(v: &ValRef) -> Result<xla::Literal> {
        match v {
            ValRef::F32(t) => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.shape,
                    bytes,
                )
                .map_err(|e| anyhow!("literal from f32 tensor {:?}: {e:?}", t.shape))
            }
            ValRef::I32(t) => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &t.shape,
                    bytes,
                )
                .map_err(|e| anyhow!("literal from i32 tensor {:?}: {e:?}", t.shape))
            }
        }
    }

    fn from_literal(lit: xla::Literal) -> Result<HostValue> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("output literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e:?}"))?;
                Ok(HostValue::F32(Tensor::new(dims, data)?))
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec<i32>: {e:?}"))?;
                Ok(HostValue::I32(TensorI32::new(dims, data)?))
            }
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::Path;
    use std::rc::Rc;

    use anyhow::{bail, Result};

    use super::super::manifest::GraphSpec;
    use super::super::value::{HostValue, ValRef};
    use super::RuntimeStats;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: this binary was built without the `pjrt` \
         feature (the external `xla` bindings crate is not vendored). Host-side \
         paths — linalg kernels, reference optimizers, `cargo bench --bench \
         bench_opt_step` — work without it.";

    /// API-compatible stand-in for the PJRT runtime. `cpu()` always fails,
    /// so the other methods are unreachable in practice but keep the same
    /// signatures for callers.
    pub struct Runtime {
        pub stats: std::cell::RefCell<RuntimeStats>,
    }

    pub struct LoadedGraph {
        pub spec: GraphSpec,
    }

    impl Runtime {
        pub fn cpu(_artifacts_dir: &Path) -> Result<Runtime> {
            bail!("{UNAVAILABLE}")
        }

        pub fn load(&self, _spec: &GraphSpec) -> Result<Rc<LoadedGraph>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn execute(&self, _g: &LoadedGraph, _inputs: &[HostValue]) -> Result<Vec<HostValue>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn execute_refs(&self, _g: &LoadedGraph, _inputs: &[ValRef]) -> Result<Vec<HostValue>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn run(&self, _spec: &GraphSpec, _inputs: &[HostValue]) -> Result<Vec<HostValue>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn run_refs(&self, _spec: &GraphSpec, _inputs: &[ValRef]) -> Result<Vec<HostValue>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn stats_snapshot(&self) -> RuntimeStats {
            self.stats.borrow().clone()
        }
    }
}

pub use backend::{LoadedGraph, Runtime};
