//! artifacts/manifest.json — the cross-language contract written by aot.py.
//!
//! The manifest is the *only* place model dimensions, parameter tables and
//! graph IO orders are declared; the coordinator never hard-codes them.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub scalar_layout: Vec<String>,
    pub presets: BTreeMap<String, Preset>,
}

#[derive(Debug, Clone)]
pub struct Preset {
    pub model: ModelDims,
    pub params: Vec<ParamSpec>,
    pub lora_params: Vec<ParamSpec>,
    pub graphs: BTreeMap<String, GraphSpec>,
    /// method -> "MxN" (or "N" for vectors) -> step graph
    pub opt_steps: BTreeMap<String, BTreeMap<String, GraphSpec>>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelDims {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub rank: usize,
    pub oversample: usize,
    pub d_ff: usize,
    pub n_cls: usize,
}

impl ModelDims {
    /// Total parameter count of the LM (without classification head).
    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let per_layer = 4 * d * d + 2 * d * self.d_ff + 4 * d;
        self.vocab * d + self.seq * d + self.n_layers * per_layer + 2 * d
    }

    pub fn l(&self) -> usize {
        self.rank + self.oversample
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: String, // matrix | vector | embed | head (lora adapters: "lora")
    pub compressed: bool,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Key into `opt_steps[method]`: "MxN" / "N".
    pub fn shape_key(&self) -> String {
        self.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
    pub rank: usize,
    pub l: usize,
    pub hparams: Json,
}

impl GraphSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|io| io.name == name)
            .ok_or_else(|| anyhow!("graph {} has no input '{name}'", self.file))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o == name)
            .ok_or_else(|| anyhow!("graph {} has no output '{name}'", self.file))
    }

    pub fn hparam_f32(&self, key: &str, default: f32) -> f32 {
        self.hparams
            .get(key)
            .and_then(|v| v.as_f64().ok())
            .map(|x| x as f32)
            .unwrap_or(default)
    }
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let json = Json::from_file(&path)?;
        let scalar_layout = json
            .req("scalar_layout")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let mut presets = BTreeMap::new();
        for (name, p) in json.req("presets")?.as_obj()? {
            presets.insert(
                name.clone(),
                parse_preset(p).with_context(|| format!("preset '{name}'"))?,
            );
        }
        Ok(Manifest { root: artifacts_dir.to_path_buf(), scalar_layout, presets })
    }

    pub fn preset(&self, name: &str) -> Result<&Preset> {
        self.presets
            .get(name)
            .ok_or_else(|| anyhow!("preset '{name}' not in manifest (have: {:?}); run `make artifacts`",
                self.presets.keys().collect::<Vec<_>>()))
    }
}

impl Preset {
    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(name)
            .ok_or_else(|| anyhow!("graph '{name}' not lowered for this preset"))
    }

    pub fn opt_step(&self, method: &str, shape_key: &str) -> Result<&GraphSpec> {
        self.opt_steps
            .get(method)
            .and_then(|m| m.get(shape_key))
            .ok_or_else(|| anyhow!("no opt step for method '{method}' shape '{shape_key}'"))
    }

    pub fn param(&self, name: &str) -> Result<&ParamSpec> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow!("unknown param '{name}'"))
    }

    /// Parameters of the LM graph (everything except the cls head).
    pub fn lm_params(&self) -> Vec<&ParamSpec> {
        self.params.iter().filter(|p| p.kind != "head").collect()
    }
}

fn parse_preset(p: &Json) -> Result<Preset> {
    let m = p.req("model")?;
    let model = ModelDims {
        d_model: m.req("d_model")?.as_usize()?,
        n_layers: m.req("n_layers")?.as_usize()?,
        n_heads: m.req("n_heads")?.as_usize()?,
        vocab: m.req("vocab")?.as_usize()?,
        seq: m.req("seq")?.as_usize()?,
        batch: m.req("batch")?.as_usize()?,
        rank: m.req("rank")?.as_usize()?,
        oversample: m.req("oversample")?.as_usize()?,
        d_ff: m.req("d_ff")?.as_usize()?,
        n_cls: m.req("n_cls")?.as_usize()?,
    };
    let params = p
        .req("params")?
        .as_arr()?
        .iter()
        .map(parse_param)
        .collect::<Result<Vec<_>>>()?;
    let lora_params = p
        .req("lora_params")?
        .as_arr()?
        .iter()
        .map(|j| {
            Ok(ParamSpec {
                name: j.req("name")?.as_str()?.to_string(),
                shape: j.req("shape")?.shape()?,
                kind: "lora".to_string(),
                compressed: false,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let mut graphs = BTreeMap::new();
    for (name, g) in p.req("graphs")?.as_obj()? {
        graphs.insert(name.clone(), parse_graph(g).with_context(|| format!("graph '{name}'"))?);
    }
    let mut opt_steps = BTreeMap::new();
    for (method, shapes) in p.req("opt_steps")?.as_obj()? {
        let mut by_shape = BTreeMap::new();
        for (key, g) in shapes.as_obj()? {
            by_shape.insert(
                key.clone(),
                parse_graph(g).with_context(|| format!("opt step {method}/{key}"))?,
            );
        }
        opt_steps.insert(method.clone(), by_shape);
    }
    Ok(Preset { model, params, lora_params, graphs, opt_steps })
}

fn parse_param(j: &Json) -> Result<ParamSpec> {
    Ok(ParamSpec {
        name: j.req("name")?.as_str()?.to_string(),
        shape: j.req("shape")?.shape()?,
        kind: j.req("kind")?.as_str()?.to_string(),
        compressed: j.req("compressed")?.as_bool()?,
    })
}

fn parse_graph(j: &Json) -> Result<GraphSpec> {
    let inputs = j
        .req("inputs")?
        .as_arr()?
        .iter()
        .map(|io| {
            Ok(IoSpec {
                name: io.req("name")?.as_str()?.to_string(),
                shape: io.req("shape")?.shape()?,
                dtype: io.req("dtype")?.as_str()?.to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let outputs = j
        .req("outputs")?
        .as_arr()?
        .iter()
        .map(|o| Ok(o.as_str()?.to_string()))
        .collect::<Result<Vec<_>>>()?;
    if outputs.is_empty() {
        bail!("graph has no outputs");
    }
    Ok(GraphSpec {
        file: j.req("file")?.as_str()?.to_string(),
        inputs,
        outputs,
        rank: j.get("rank").and_then(|v| v.as_usize().ok()).unwrap_or(0),
        l: j.get("l").and_then(|v| v.as_usize().ok()).unwrap_or(0),
        hparams: j.get("hparams").cloned().unwrap_or(Json::Obj(Default::default())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fsutil;

    #[test]
    fn loads_real_manifest_if_present() {
        // Structural validation against the artifacts built by `make
        // artifacts`; skipped when artifacts are absent (pure-rust CI).
        let dir = match fsutil::artifacts_dir() {
            Ok(d) if d.join("manifest.json").exists() => d,
            _ => return,
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.scalar_layout[0], "lr");
        let p = m.preset("nano").unwrap();
        assert_eq!(p.model.d_model, 64);
        // every param with kind matrix must have a shape entry in opt_steps
        // for at least the adamw method
        for param in &p.params {
            if param.compressed {
                assert!(
                    p.opt_step("adamw", &param.shape_key()).is_ok()
                        || p.opt_step("mlorc_adamw", &param.shape_key()).is_ok(),
                    "no step graph for {}",
                    param.name
                );
            }
        }
        // graph IO tables are self-consistent
        let g = p.graph("fwd_bwd").unwrap();
        assert_eq!(g.inputs.len(), p.lm_params().len() + 2);
        assert_eq!(g.outputs.len(), p.lm_params().len() + 1);
        assert_eq!(g.input_index("tokens").unwrap(), 0);
        assert!(g.output_index("loss").unwrap() == 0);
    }

    #[test]
    fn n_params_formula() {
        let dims = ModelDims {
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            vocab: 256,
            seq: 32,
            batch: 4,
            rank: 4,
            oversample: 0,
            d_ff: 256,
            n_cls: 2,
        };
        // embed 256*64 + pos 32*64 + 2*(4*64^2 + 2*64*256 + 4*64) + 2*64
        let want = 256 * 64 + 32 * 64 + 2 * (4 * 64 * 64 + 2 * 64 * 256 + 4 * 64) + 2 * 64;
        assert_eq!(dims.n_params(), want);
    }
}
