//! mlorc — launcher CLI for the MLorc reproduction.
//!
//! Subcommands:
//!   train   — one fine-tuning run (method x task x preset)
//!   submit  — enqueue a fine-tuning job into a serve spool
//!   serve   — drain a spool with N concurrent jobs (crash-safe resume)
//!   status  — aggregate per-job status across a spool
//!   top     — merge per-scheduler metrics snapshots across a spool
//!   cancel  — tombstone a queued job (atomic rename into cancelled/)
//!   fsck    — verify (and repair) a spool's checkpoint snapshots
//!   bench   — regenerate a paper table/figure (see DESIGN.md §5)
//!   info    — artifact/manifest inventory
//!   memory  — analytic memory report for a preset (Table 1 style)

use std::path::Path;

use anyhow::{bail, Context, Result};

use mlorc::bench_harness::{run_experiment, Scale, EXPERIMENT_IDS};
use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::obs::registry;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::serve::{self, Engine, JobSpec, ServeOpts, Spool};
use mlorc::util::{cli::Args, fsutil, logger};

fn main() {
    logger::init();
    if let Err(e) = run() {
        log::error!("{e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("submit") => cmd_submit(&args),
        Some("serve") => cmd_serve(&args),
        Some("status") => cmd_status(&args),
        Some("top") => cmd_top(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("fsck") => cmd_fsck(&args),
        Some("bench") => cmd_bench(&args),
        Some("info") => cmd_info(&args),
        Some("memory") => cmd_memory(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try `mlorc help`)"),
    }
}

fn print_help() {
    println!(
        "mlorc — Momentum Low-rank Compression (AISTATS 2026) reproduction

USAGE: mlorc <subcommand> [--options]

  train  --preset tiny --method mlorc_adamw --task math_chain --steps 200
         [--lr 2e-3] [--seed 0] [--eval-every 50] [--spectral-every 0]
         [--host-opt] [--opt-threads N] [--rank-min N]
         [--save-metrics results/run.json]
         [--checkpoint-dir ckpt/] [--checkpoint-every N] [--resume ckpt/]
         [--checkpoint-sync]
  submit --spool spool/ --method mlorc_adamw --steps 200
         [--engine host|graph] [--preset <name>] [--task <t>] [--lr X]
         [--seed N] [--checkpoint-every N] [--priority N] [--rank-min N]
         [--id jobNNN_name]
  serve  --spool spool/ [--jobs 2] [--drain] [--poll-ms 500]
         [--max-retries 2] [--retry-backoff-ms 500]
         [--lease-timeout-ms 30000] [--failpoint site:action@N]
         [--checkpoint-sync]
  status --spool spool/ [--json] [--expect-all-done]
  top    --spool spool/ [--json]
  cancel <job-id> [--spool spool/]
  fsck   <spool/> [--repair] [--json]
  bench  --experiment <id> [--quick] [--steps N] [--seeds K]
         ids: {ids}
  memory --preset tiny [--per-layer]
  info

methods: {methods}
tasks:   math_chain, stack_code, synglue_<{glue}>
host engine presets (no artifacts needed): {hosts}",
        ids = EXPERIMENT_IDS.join(", "),
        methods = Method::all().iter().map(|m| m.name()).collect::<Vec<_>>().join(", "),
        glue = mlorc::data::SYNGLUE_NAMES.join("|"),
        hosts = serve::host_preset_names().join(", "),
    );
}

fn open_runtime() -> Result<(Manifest, Runtime)> {
    let dir = fsutil::artifacts_dir()?;
    if !dir.join("manifest.json").exists() {
        bail!("no artifacts at {} — run `make artifacts` first", dir.display());
    }
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    Ok((manifest, rt))
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "tiny").to_string();
    let method = Method::parse(args.get_or("method", "mlorc_adamw"))?;
    let task = TaskKind::parse(args.get_or("task", "math_chain"))?;
    let steps = args.get_usize("steps", 200)?;
    let mut cfg = RunConfig::new(&preset, method, task, steps);
    cfg.peak_lr = args.get_f64("lr", cfg.peak_lr as f64)? as f32;
    cfg.seed = args.get_u64("seed", 0)?;
    cfg.eval_every = args.get_usize("eval-every", 0)?;
    cfg.eval_batches = args.get_usize("eval-batches", 8)?;
    cfg.spectral_every = args.get_usize("spectral-every", 0)?;
    cfg.galore_update_freq = args.get_usize("galore-freq", 50)?;
    cfg.rank_min = args.get_usize("rank-min", 1)?;
    cfg.host_opt = args.flag("host-opt");
    cfg.opt_threads = args.get_usize("opt-threads", 0)?;
    cfg.log_every = args.get_usize("log-every", 10)?;
    let save_metrics = args.get("save-metrics").map(|s| s.to_string());
    let ckpt_dir = args.get("checkpoint-dir").map(|s| s.to_string());
    let ckpt_every = args.get_usize("checkpoint-every", 0)?;
    let ckpt_sync = args.flag("checkpoint-sync");
    let resume = args.get("resume").map(|s| s.to_string());
    args.reject_unknown()?;
    if ckpt_every > 0 && ckpt_dir.is_none() {
        bail!("--checkpoint-every {ckpt_every} needs --checkpoint-dir <dir> to write into");
    }

    let (manifest, rt) = open_runtime()?;
    let preset_spec = manifest.preset(&preset)?;
    log::info!(
        "train: {} / {} / {} — {} params, rank {}",
        preset,
        method.name(),
        task.name(),
        preset_spec.model.n_params(),
        preset_spec.model.rank
    );
    let mut trainer = Trainer::new(&rt, preset_spec, cfg.clone())?;
    if let Some(dir) = &resume {
        let step = trainer.resume_from(Path::new(dir))?;
        log::info!("resumed from {dir} at step {step} (v2 optimizer state + RNG streams restored)");
    }
    let outcome = trainer.train_with_checkpoint_mode(
        ckpt_every,
        ckpt_dir.as_deref().map(Path::new),
        ckpt_sync,
    )?;
    if let Some(ev) = &outcome.eval {
        log::info!(
            "done: final loss {:.4}, eval loss {:.4}, acc {:.3}, exact match {:.3} ({:.1}s)",
            outcome.final_loss,
            ev.loss,
            ev.accuracy,
            ev.exact_match,
            outcome.wall_secs
        );
    }
    let mem = &outcome.memory_measured;
    log::info!(
        "memory: weights {:.1} MB, opt state {:.1} MB, grads peak {:.1} MB",
        mem.weights_bytes as f64 / 1e6,
        mem.opt_state_bytes as f64 / 1e6,
        mem.grads_peak_bytes as f64 / 1e6
    );
    if let Some(path) = save_metrics {
        trainer.metrics.save(std::path::Path::new(&path))?;
        log::info!("metrics -> {path}");
    }
    if let Some(dir) = ckpt_dir {
        // train_with_checkpoints already wrote the final v2 snapshot
        // (params + full optimizer state + RNG streams) into the root.
        log::info!("checkpoint (v2, resumable) -> {dir}");
    }
    Ok(())
}

fn cmd_submit(args: &Args) -> Result<()> {
    let spool_dir = args.get_or("spool", "spool").to_string();
    let engine = Engine::parse(args.get_or("engine", "host"))?;
    let default_preset = match engine {
        Engine::Host => "host-nano",
        Engine::Graph => "tiny",
    };
    let preset = args.get_or("preset", default_preset).to_string();
    let method = Method::parse(args.get_or("method", "mlorc_adamw"))?;
    let task = TaskKind::parse(args.get_or("task", "math_chain"))?;
    let steps = args.get_usize("steps", 200)?;
    let mut cfg = RunConfig::new(&preset, method, task, steps);
    cfg.peak_lr = args.get_f64("lr", cfg.peak_lr as f64)? as f32;
    cfg.seed = args.get_u64("seed", 0)?;
    cfg.opt_threads = args.get_usize("opt-threads", 0)?;
    cfg.rank_min = args.get_usize("rank-min", 1)?;
    cfg.host_opt = args.flag("host-opt");
    cfg.log_every = 0;
    let checkpoint_every = args.get_usize("checkpoint-every", 10)?;
    let priority = args.get_i64("priority", 0)?;
    let id = args.get("id").map(|s| s.to_string());
    args.reject_unknown()?;

    let spool = Spool::open(Path::new(&spool_dir))?;
    let id = match id {
        Some(i) => i,
        None => spool.next_job_id(method.name())?,
    };
    let spec =
        JobSpec { id, engine, checkpoint_every, priority, attempts: Vec::new(), not_before_unix_ms: 0, cfg };
    let path = spool.submit(&spec)?;
    println!("submitted {} -> {}", spec.id, path.display());
    Ok(())
}

fn cmd_cancel(args: &Args) -> Result<()> {
    let spool_dir = args.get_or("spool", "spool").to_string();
    // accept the id either positionally (`mlorc cancel job001_x`) or as
    // `--id job001_x` — read the option unconditionally so reject_unknown
    // never mislabels the documented --id as unknown
    let opt_id = args.get("id").map(|s| s.to_string());
    let id = args.positional.first().cloned().or(opt_id);
    args.reject_unknown()?;
    let Some(id) = id else {
        bail!("usage: mlorc cancel <job-id> [--spool dir]");
    };
    let spool = Spool::open(Path::new(&spool_dir))?;
    spool.cancel(&id)?;
    println!("cancelled {id} (tombstoned in {spool_dir}/cancelled/)");
    Ok(())
}

fn cmd_fsck(args: &Args) -> Result<()> {
    // accept the spool either positionally (`mlorc fsck spool/`) or as
    // `--spool spool/`, defaulting like the other subcommands
    let opt_spool = args.get("spool").map(|s| s.to_string());
    let spool_dir =
        args.positional.first().cloned().or(opt_spool).unwrap_or_else(|| "spool".to_string());
    let repair = args.flag("repair");
    let as_json = args.flag("json");
    args.reject_unknown()?;
    let spool = Spool::open(Path::new(&spool_dir))?;
    let report = serve::fsck(&spool, repair)?;
    if as_json {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("{}", serve::render_report(&report));
    }
    if !report.clean() {
        bail!(
            "spool {spool_dir} has integrity problems{}",
            if repair { " that could not be repaired" } else { " (re-run with --repair)" }
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let spool_dir = args.get_or("spool", "spool").to_string();
    let opts = ServeOpts {
        jobs: args.get_usize("jobs", 2)?,
        drain: args.flag("drain"),
        poll_ms: args.get_u64("poll-ms", 500)?,
        die_after_checkpoints: args.get_usize("die-after-checkpoints", 0)?,
        max_retries: args.get_usize("max-retries", 2)?,
        retry_backoff_ms: args.get_u64("retry-backoff-ms", 500)?,
        checkpoint_sync: args.flag("checkpoint-sync"),
        lease_timeout_ms: args.get_u64("lease-timeout-ms", 30_000)?,
    };
    // fault-injection hook (same grammar as MLORC_FAILPOINT)
    if let Some(spec) = args.get("failpoint") {
        fsutil::failpoints::arm(spec)?;
    }
    args.reject_unknown()?;
    let spool = Spool::open(Path::new(&spool_dir))?;
    let summary = serve::serve(&spool, &opts)?;
    log::info!(
        "serve: {} done, {} failed, {} retried ({} recovered at startup)",
        summary.done,
        summary.failed,
        summary.retried,
        summary.recovered
    );
    if summary.failed > 0 {
        bail!("{} job(s) failed — see {}/status/", summary.failed, spool_dir);
    }
    Ok(())
}

fn cmd_status(args: &Args) -> Result<()> {
    let spool_dir = args.get_or("spool", "spool").to_string();
    let as_json = args.flag("json");
    let expect_all_done = args.flag("expect-all-done");
    args.reject_unknown()?;
    let spool = Spool::open(Path::new(&spool_dir))?;
    let rows = serve::aggregate(&spool)?;
    if as_json {
        println!(
            "{}",
            mlorc::util::json::Json::arr(rows.iter().map(|r| r.to_json())).to_string_pretty()
        );
    } else {
        println!("{}", serve::render_table(&rows));
    }
    if expect_all_done {
        if rows.is_empty() {
            bail!("spool {spool_dir} has no jobs");
        }
        // cancelled jobs were tombstoned on purpose; they don't block a
        // clean drain
        let not_done =
            rows.iter().filter(|r| r.state != "done" && r.state != "cancelled").count();
        if not_done > 0 {
            bail!("{not_done} job(s) not done");
        }
    }
    Ok(())
}

fn cmd_top(args: &Args) -> Result<()> {
    let spool_dir = args.get_or("spool", "spool").to_string();
    let as_json = args.flag("json");
    args.reject_unknown()?;
    let spool = Spool::open(Path::new(&spool_dir))?;
    let dir = spool.metrics_dir();
    let mut paths: Vec<_> = std::fs::read_dir(&dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    let mut snaps = Vec::new();
    let mut schedulers = Vec::new();
    for p in &paths {
        match mlorc::util::json::Json::from_file(p) {
            Ok(j) => {
                let schema = j.get("schema").and_then(|s| s.as_str().ok().map(|s| s.to_string()));
                if schema.as_deref() != Some("mlorc_metrics/v1") {
                    log::warn!("top: skipping {} (unknown schema {schema:?})", p.display());
                    continue;
                }
                if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                    schedulers.push(stem.to_string());
                }
                snaps.push(j);
            }
            Err(e) => log::warn!("top: skipping unreadable {}: {e:#}", p.display()),
        }
    }
    let merged = registry::merge_snapshots(&snaps);
    if as_json {
        println!("{}", merged.to_string_pretty());
        return Ok(());
    }
    if snaps.is_empty() {
        println!(
            "spool {spool_dir}: no metrics snapshots under {} yet \
             (run `mlorc serve`; snapshots are disabled when MLORC_NO_OBS is set)",
            dir.display()
        );
        return Ok(());
    }
    println!(
        "spool {spool_dir}: {} scheduler snapshot(s): {}",
        snaps.len(),
        schedulers.join(", ")
    );
    println!("\ncounters");
    for (name, v) in merged.req("counters")?.as_obj()? {
        println!("  {name:<24} {:>14}", v.as_f64()? as u64);
    }
    println!("\ngauges (max across schedulers)");
    for (name, v) in merged.req("gauges")?.as_obj()? {
        println!("  {name:<24} {:>14}", v.as_f64()? as u64);
    }
    println!("\nhistograms (µs; p50/p90/p99 are bucket upper bounds)");
    println!(
        "  {:<24} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "name", "count", "p50", "p90", "p99", "mean"
    );
    for (name, h) in merged.req("histograms")?.as_obj()? {
        let count = h.req("count")?.as_f64()?;
        if count == 0.0 {
            continue;
        }
        let mean = h.req("sum")?.as_f64()? / count;
        println!(
            "  {:<24} {:>10} {:>10} {:>10} {:>10} {:>12.1}",
            name,
            count as u64,
            registry::snapshot_percentile(h, 0.50),
            registry::snapshot_percentile(h, 0.90),
            registry::snapshot_percentile(h, 0.99),
            mean
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let id = args.require("experiment")?.to_string();
    let scale = if args.flag("quick") { Scale::Quick } else { Scale::Full };
    let steps = args.get("steps").map(|s| s.parse()).transpose().context("--steps")?;
    let seeds = args.get("seeds").map(|s| s.parse()).transpose().context("--seeds")?;
    args.reject_unknown()?;
    let (manifest, rt) = open_runtime()?;
    let ids: Vec<String> = if id == "all" {
        EXPERIMENT_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        id.split(',').map(|s| s.to_string()).collect()
    };
    let out_dir = fsutil::results_dir()?;
    for id in ids {
        log::info!("experiment {id} ({scale:?})...");
        let t0 = std::time::Instant::now();
        let report = run_experiment(&id, &manifest, &rt, scale, steps, seeds)?;
        report.save(&out_dir)?;
        println!("{}", report.to_markdown());
        log::info!("{id} done in {:.1}s -> results/{id}.md", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    let dir = fsutil::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    for (name, preset) in &manifest.presets {
        let dims = preset.model;
        println!(
            "  preset {name}: d={} L={} heads={} vocab={} seq={} batch={} rank={} — {:.1}M params, {} graphs, {} opt-step methods",
            dims.d_model,
            dims.n_layers,
            dims.n_heads,
            dims.vocab,
            dims.seq,
            dims.batch,
            dims.rank,
            dims.n_params() as f64 / 1e6,
            preset.graphs.len(),
            preset.opt_steps.len(),
        );
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let preset_name = args.get_or("preset", "tiny").to_string();
    let per_layer = args.flag("per-layer");
    args.reject_unknown()?;
    let dir = fsutil::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let preset = manifest.preset(&preset_name)?;
    println!(
        "analytic memory for preset '{preset_name}' (per-layer updates: {per_layer}), {:.1}M params:",
        preset.model.n_params() as f64 / 1e6
    );
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>12}",
        "method", "weights", "opt state", "grads peak", "total"
    );
    for &m in Method::all() {
        let r = mlorc::coordinator::MemoryAccountant::analytic(preset, m, per_layer, false);
        println!(
            "{:<14} {:>10.1}MB {:>10.1}MB {:>10.1}MB {:>10.1}MB",
            m.name(),
            (r.weights_bytes + r.lora_extra_weights_bytes) as f64 / 1e6,
            r.opt_state_bytes as f64 / 1e6,
            r.grads_peak_bytes as f64 / 1e6,
            r.total() as f64 / 1e6
        );
    }
    Ok(())
}
