//! Vendored minimal `log` facade.
//!
//! The offline build has no crates.io access, so this crate re-implements
//! the subset of the `log` 0.4 API the workspace uses: the five level
//! macros, `Level`/`LevelFilter`, the `Log` trait, `set_logger` /
//! `set_max_level`, and `Record`/`Metadata` accessors. Semantics match the
//! upstream facade for that subset (global `&'static dyn Log`, max-level
//! gate checked before dispatch).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logging verbosity of one record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum verbosity accepted by the installed logger.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Static metadata of a record (level + target module path).
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log message, borrowed from the emitting macro's stack frame.
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Backend trait; implement and install with [`set_logger`].
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger was already installed")
    }
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // LevelFilter::Off

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — public because the expansion site lives in downstream
/// crates, not intended to be called directly.
#[doc(hidden)]
pub fn __private_api_log(args: fmt::Arguments, level: Level, target: &str) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log(format_args!($($arg)+), $lvl, module_path!())
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= LevelFilter::Info
        }
        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                HITS.fetch_add(1, Ordering::Relaxed);
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn level_gating_and_dispatch() {
        static COUNTER: Counter = Counter;
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = HITS.load(Ordering::Relaxed);
        info!("hello {}", 1);
        debug!("filtered out");
        let after = HITS.load(Ordering::Relaxed);
        assert_eq!(after - before, 1);
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
