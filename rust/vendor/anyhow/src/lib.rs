//! Vendored minimal `anyhow`.
//!
//! The offline build has no crates.io access, so this crate provides the
//! subset of the `anyhow` 1.x API the workspace uses: `Error` (message +
//! context chain), `Result<T>`, the `anyhow!` / `bail!` / `ensure!`
//! macros, and the `Context` extension trait on `Result` and `Option`.
//! Formatting matches upstream where it matters: `{}` prints the topmost
//! context, `{:#}` prints the whole chain joined by `": "`, and `{:?}`
//! prints the top message followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// Dynamic error: a message with an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap `self` as the cause of a new, higher-level message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain from the outermost context to the root cause.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(cause) = cur.cause.as_deref() {
            cur = cause;
        }
        cur
    }
}

pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.cause.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, err) in self.chain().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(&err.msg)?;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, err) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {}", err.msg)?;
            }
        }
        Ok(())
    }
}

// Matches upstream: `Error` itself does not implement `std::error::Error`,
// which is what makes this blanket conversion coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn StdError + 'static)> = Some(&err);
        while let Some(e) = cur {
            chain.push(e.to_string());
            cur = e.source();
        }
        let mut out = Error::msg(chain.pop().expect("at least one message"));
        while let Some(msg) = chain.pop() {
            out = out.context(msg);
        }
        out
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// A single impl bound on `Into<Error>` covers both `Result<T, Error>`
// (identity conversion) and results carrying std errors (the blanket
// `From` above) without overlapping impls.
impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => { $crate::Error::msg(format!($($arg)+)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => { return Err($crate::anyhow!($($arg)+)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            $crate::bail!($($arg)+)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root failure {}", 7)
    }

    #[test]
    fn display_and_chain() {
        let err = fails().context("while doing work").unwrap_err();
        assert_eq!(format!("{err}"), "while doing work");
        assert_eq!(format!("{err:#}"), "while doing work: root failure 7");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert_eq!(err.root_cause().to_string(), "root failure 7");
        assert_eq!(err.chain().count(), 2);
    }

    #[test]
    fn std_error_conversion_and_option_context() {
        let io: std::io::Error = std::io::Error::new(std::io::ErrorKind::Other, "disk gone");
        let err: Error = io.into();
        assert!(format!("{err}").contains("disk gone"));

        let none: Option<u32> = None;
        let err = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{err}"), "missing key");

        let ok: Option<u32> = Some(3);
        assert_eq!(ok.context("unused").unwrap(), 3);
    }

    #[test]
    fn ensure_macro() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
    }
}
