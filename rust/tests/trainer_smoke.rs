//! End-to-end coordinator smoke tests on the nano preset: every method
//! must run steps, decrease training loss on math-chain, and produce a
//! coherent memory report. Skipped when artifacts are absent.

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::Trainer;
use mlorc::runtime::{Manifest, Runtime};
use mlorc::util::fsutil;

fn setup() -> Option<(Manifest, Runtime)> {
    let dir = fsutil::artifacts_dir().ok()?;
    if !dir.join("manifest.json").exists() {
        return None;
    }
    Some((Manifest::load(&dir).unwrap(), Runtime::cpu(&dir).unwrap()))
}

fn short_run(method: Method, task: TaskKind, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::new("nano", method, task, steps);
    cfg.log_every = 0;
    cfg.eval_batches = 2;
    // nano-scale LRs: higher than the 7B-scale defaults
    cfg.peak_lr = match method.name() {
        "full_lion" | "mlorc_lion" | "lora_lion" | "galore_lion" => 1e-3,
        "lora_adamw" | "galore" => 5e-3,
        _ => 3e-3,
    };
    cfg
}

#[test]
fn mlorc_adamw_reduces_loss_on_mathchain() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let cfg = short_run(Method::MlorcAdamW, TaskKind::MathChain, 30);
    let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
    let first = tr.train_step().unwrap();
    for _ in 0..29 {
        tr.train_step().unwrap();
    }
    let last = tr.metrics.smoothed_final_loss(5).unwrap();
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(last < first * 0.8, "loss barely moved: {first} -> {last}");
}

#[test]
fn every_method_runs_three_steps_lm() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    for &method in Method::all() {
        if !method.desc().graphed {
            // host-only registry combos have no lowered step graphs yet
            continue;
        }
        let cfg = short_run(method, TaskKind::MathChain, 3);
        let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
        for _ in 0..3 {
            let loss = tr.train_step().unwrap_or_else(|e| panic!("{method:?}: {e}"));
            assert!(loss.is_finite(), "{method:?} loss not finite");
        }
        let mem = tr.memory_measured();
        assert!(mem.opt_state_bytes > 0, "{method:?} no optimizer state");
    }
}

#[test]
fn cls_task_trains_and_evaluates() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    for method in [Method::MlorcAdamW, Method::LoraAdamW] {
        let mut cfg = short_run(method, TaskKind::SynGlue(6), 12); // sst2-like
        cfg.eval_batches = 4;
        let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
        for _ in 0..12 {
            tr.train_step().unwrap();
        }
        let ev = tr.evaluate().unwrap();
        assert!(ev.loss.is_finite());
        assert!((0.0..=1.0).contains(&ev.accuracy), "{method:?} acc {}", ev.accuracy);
    }
}

#[test]
fn lora_base_weights_stay_frozen() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let cfg = short_run(Method::LoraAdamW, TaskKind::MathChain, 3);
    let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
    let wq_before = tr.params.get("blk0.wq").unwrap().clone();
    let emb_before = tr.params.get("tok_emb").unwrap().clone();
    for _ in 0..3 {
        tr.train_step().unwrap();
    }
    assert_eq!(*tr.params.get("blk0.wq").unwrap(), wq_before);
    assert_eq!(*tr.params.get("tok_emb").unwrap(), emb_before);
    // adapters did move
    let a = tr.adapters.as_ref().unwrap();
    assert!(a.get("blk0.wq.lora_B").unwrap().norm_fro() > 0.0);
}

#[test]
fn memory_ranking_matches_table3() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let mut opt_bytes = std::collections::BTreeMap::new();
    for method in [Method::FullAdamW, Method::MlorcAdamW, Method::Galore, Method::LdAdamW] {
        let cfg = short_run(method, TaskKind::MathChain, 1);
        let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
        tr.train_step().unwrap();
        opt_bytes.insert(method.name(), tr.memory_measured().opt_state_bytes);
    }
    // Table 3 ordering: MLorc ≈ GaLore < LDAdamW < Full (opt state)
    assert!(opt_bytes["mlorc_adamw"] < opt_bytes["full_adamw"]);
    assert!(opt_bytes["galore"] < opt_bytes["full_adamw"]);
    assert!(opt_bytes["ldadamw"] > opt_bytes["mlorc_adamw"]);
}

#[test]
fn deterministic_given_seed() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let mut losses = Vec::new();
    for _ in 0..2 {
        let cfg = short_run(Method::MlorcAdamW, TaskKind::MathChain, 4).with_seed(123);
        let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
        let mut run = Vec::new();
        for _ in 0..4 {
            run.push(tr.train_step().unwrap());
        }
        losses.push(run);
    }
    assert_eq!(losses[0], losses[1], "same seed must reproduce the loss curve");
}

#[test]
fn spectral_probe_records_during_training() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let mut cfg = short_run(Method::FullAdamW, TaskKind::MathChain, 6);
    cfg.spectral_every = 2;
    let mut tr = Trainer::new(&rt, preset, cfg).unwrap();
    for _ in 0..6 {
        tr.train_step().unwrap();
    }
    assert_eq!(tr.metrics.spectral.len(), 3);
    for rec in &tr.metrics.spectral {
        assert!(rec.grad_ratio > 0.0 && rec.grad_ratio <= 1.0);
        assert!(rec.v_ratio > 0.0 && rec.v_ratio <= 1.0);
    }
}
