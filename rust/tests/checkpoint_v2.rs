//! Checkpoint v2 integration: byte-exact roundtrip of every `OptState`
//! variant through the on-disk format, and kill-at-step-k/resume runs
//! that must reach final parameters bit-identical to uninterrupted runs.

use std::path::PathBuf;

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::{
    load_checkpoint, load_checkpoint_v2, save_checkpoint, save_checkpoint_v2, OptSnapshot,
    OptState, ParamStore,
};
use mlorc::linalg::Rng;
use mlorc::runtime::ParamSpec;
use mlorc::serve::HostTrainer;
use mlorc::tensor::Tensor;

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mlorc_ckv2_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn dummy_store() -> ParamStore {
    ParamStore {
        specs: vec![ParamSpec {
            name: "w".into(),
            shape: vec![3, 2],
            kind: "matrix".into(),
            compressed: true,
        }],
        values: vec![Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap()],
    }
}

/// One randomly-filled state per registered variant: build the zero state
/// through the registry, then overwrite every tensor field — so any newly
/// registered (rule × compressor) combo is covered here automatically.
fn rand_states(rng: &mut Rng) -> Vec<(String, OptState)> {
    let (m, n, l) = (10usize, 14usize, 4usize);
    let mut out = vec![("frozen".to_string(), OptState::Frozen)];
    for v in mlorc::optim::registry::VARIANTS {
        let mut st = OptState::for_variant(v.id, &[m, n], l).unwrap();
        for (_, t) in st.tensor_fields_mut() {
            let shape = t.shape.clone();
            *t = rng.gaussian_tensor(&shape, 1.0);
        }
        // exercise a non-default flag on the galore layouts
        if let Some(gal) = st.galore_mut() {
            gal.refreshed = true;
        }
        out.push((v.id.to_string(), st));
    }
    out
}

#[test]
fn every_variant_roundtrips_byte_exact() {
    let dir = tmp("variants");
    let cfg = RunConfig::new("host-nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
    let params = dummy_store();
    let mut rng = Rng::new(99);
    let states = rand_states(&mut rng);
    let opt: Vec<(String, &OptState)> =
        states.iter().map(|(name, st)| (name.clone(), st)).collect();
    let mut data_rng = Rng::new(1);
    data_rng.normal(); // park a Box-Muller spare in the stream state
    let omega: Vec<Rng> = (0..states.len()).map(|i| Rng::new(50 + i as u64)).collect();
    let snap = OptSnapshot { opt, rng_data: &data_rng, omega: &omega };
    save_checkpoint_v2(&dir, 13, &cfg, &params, None, &snap).unwrap();

    let mut loaded_params = dummy_store();
    loaded_params.values[0] = Tensor::zeros(&[3, 2]);
    let back = load_checkpoint_v2(&dir, &mut loaded_params, None).unwrap();
    assert_eq!(back.step, 13);
    assert_eq!(loaded_params.values[0], params.values[0]);
    assert_eq!(back.rng_data.snapshot(), data_rng.snapshot());
    for (i, om) in omega.iter().enumerate() {
        assert_eq!(back.omega[i].snapshot(), om.snapshot(), "omega stream {i}");
    }
    assert_eq!(back.opt.len(), states.len());
    for (name, orig) in &states {
        let got = back.opt.get(name).unwrap_or_else(|| panic!("missing state '{name}'"));
        assert_eq!(got.variant_name(), orig.variant_name(), "{name}");
        assert_eq!(
            got.ckpt_meta().to_string_compact(),
            orig.ckpt_meta().to_string_compact(),
            "{name} flags"
        );
        let (a, b) = (orig.tensor_fields(), got.tensor_fields());
        assert_eq!(a.len(), b.len(), "{name} field count");
        for ((fa, ta), (fb, tb)) in a.iter().zip(&b) {
            assert_eq!(fa, fb, "{name} field order");
            assert_eq!(ta.shape, tb.shape, "{name}/{fa} shape");
            assert_eq!(ta.data, tb.data, "{name}/{fa} bytes");
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn v1_directory_rejected_with_structured_error() {
    let dir = tmp("v1guard");
    let cfg = RunConfig::new("host-nano", Method::MlorcAdamW, TaskKind::MathChain, 10);
    let params = dummy_store();
    save_checkpoint(&dir, 4, &cfg, &params, None).unwrap();
    // v1 loader still reads it (params only)...
    let mut p = dummy_store();
    assert_eq!(load_checkpoint(&dir, &mut p).unwrap(), 4);
    // ...but a v2 load names the problem instead of a shape mismatch
    let err = load_checkpoint_v2(&dir, &mut p, None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("format v1"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill at step k, resume, finish: final params must be bit-identical to
/// a run that was never interrupted. Exercised for both MLorc flavors
/// the issue pins, the projection baselines (whose projector state +
/// refresh flags must survive the checkpoint), and the post-refactor
/// registry combos (`mlorc_sgdm`, `galore_lion`) — end-to-end train +
/// checkpoint-resume bit-identity for the new methods.
#[test]
fn kill_and_resume_bit_identical() {
    for (method, tag) in [
        (Method::MlorcAdamW, "ma"),
        (Method::MlorcLion, "ml"),
        (Method::Galore, "ga"),
        (Method::LdAdamW, "ld"),
        (Method::MlorcSgdM, "ms"),
        (Method::GaloreLion, "gl"),
    ] {
        let mut cfg = RunConfig::new("host-nano", method, TaskKind::MathChain, 14);
        cfg.peak_lr = 0.03;
        cfg.log_every = 0;
        cfg.seed = 5;
        cfg.galore_update_freq = 4; // several refreshes, one mid-segment
        // uninterrupted reference
        let mut full = HostTrainer::new(cfg.clone()).unwrap();
        for _ in 0..14 {
            full.train_step().unwrap();
        }
        // interrupted at step 6
        let dir = tmp(&format!("resume_{tag}"));
        let mut first = HostTrainer::new(cfg.clone()).unwrap();
        for _ in 0..6 {
            first.train_step().unwrap();
        }
        first.save_checkpoint(&dir).unwrap();
        drop(first); // the "kill"
        let mut resumed = HostTrainer::new(cfg.clone()).unwrap();
        assert_eq!(resumed.resume_from(&dir).unwrap(), 6);
        for _ in 0..8 {
            resumed.train_step().unwrap();
        }
        assert_eq!(resumed.step_count(), 14);
        for (i, (a, b)) in
            full.params.values.iter().zip(&resumed.params.values).enumerate()
        {
            assert_eq!(a.data, b.data, "{method:?} param {i} diverged after resume");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn resume_rejects_mismatched_method() {
    let dir = tmp("mismatch");
    let mut cfg = RunConfig::new("host-nano", Method::MlorcAdamW, TaskKind::MathChain, 6);
    cfg.log_every = 0;
    let mut tr = HostTrainer::new(cfg.clone()).unwrap();
    tr.train_step().unwrap();
    tr.save_checkpoint(&dir).unwrap();
    let other = RunConfig::new("host-nano", Method::MlorcLion, TaskKind::MathChain, 6);
    let mut wrong = HostTrainer::new(other).unwrap();
    assert!(wrong.resume_from(&dir).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}
