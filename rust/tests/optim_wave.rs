//! Numerical property suite for the second optimizer wave (PR 10):
//!
//! * bf16 stochastic rounding is unbiased — the mean rounding error over
//!   10k draws sits within 3σ of zero, and on-grid values are fixed
//!   points of both the stochastic and the round-to-nearest paths.
//! * Prodigy's D estimate never decreases and its `d`/`d_numerator`
//!   recurrences replay exactly against an independent scalar oracle.
//! * The 1D effective-shape fold is a pure view: every 1D preset shape
//!   folds to a valid factored shape, the parameter's shape is restored
//!   after each step, and the folded run is bit-identical to stepping
//!   the same data as a native 2D parameter.
//! * The composable modifiers obey their defining identities: OrthoGrad
//!   output is orthogonal to the weight and norm-preserving, Grams
//!   updates sign-match the gradient, and the atan2 apply is bounded
//!   and matches `m̂/√v̂` near zero.
//!
//! Everything here is deterministic, so the suite must pass unchanged
//! under `MLORC_THREADS` budgets 1 and 8 and with `MLORC_NO_SIMD=1`
//! (wired into CI's portable job).

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::OptState;
use mlorc::linalg::{threads, Rng, Workspace};
use mlorc::optim::registry::effective_shape;
use mlorc::optim::rules::{PRODIGY_D0, PRODIGY_D_COEF, PRODIGY_SLICE_P};
use mlorc::optim::{
    bf16_to_f32, f32_to_bf16_stochastic, orthogonalize_gradient, prodigy_bc, round_to_nearest,
    OptHp, ProdigyState, ATAN2_SCALE,
};
use mlorc::serve::HostTrainer;
use mlorc::testing::prop;
use mlorc::tensor::Tensor;

// ------------------------------------------------- stochastic rounding

/// E[bf16_stochastic(x)] == x: over N draws the sample mean of the
/// rounding error must land within 3 standard errors of zero. With
/// N = 10_000 a biased rounder (e.g. truncation, whose error mean is
/// half the grid gap) fails by orders of magnitude.
#[test]
fn stochastic_rounding_error_mean_is_within_3_sigma_of_zero() {
    let mut rng = Rng::new(0x5eed);
    const N: usize = 10_000;
    for case in 0..8 {
        // off-grid magnitudes across several exponent ranges
        let x = (rng.normal() as f32) * 10f32.powi(case - 4) + 1e-7;
        let mut sum = 0f64;
        let mut sumsq = 0f64;
        for _ in 0..N {
            let r = rng.next_u64() as u16;
            let err = (bf16_to_f32(f32_to_bf16_stochastic(x, r)) - x) as f64;
            sum += err;
            sumsq += err * err;
        }
        let mean = sum / N as f64;
        let var = (sumsq / N as f64 - mean * mean).max(0.0);
        let se = (var / N as f64).sqrt();
        // gap/2 bias slack only through the 3σ band: on-grid x gives
        // se == 0 == mean and passes exactly
        assert!(
            mean.abs() <= 3.0 * se + 1e-30,
            "biased rounding for x={x}: mean err {mean:e} vs 3σ {:e}",
            3.0 * se
        );
    }
}

/// The degenerate cases: values already on the bf16 grid are fixed
/// points of both rounders (any draw), and round-to-nearest picks the
/// closer neighbour of every off-grid value.
#[test]
fn rounding_degenerate_cases() {
    let mut rng = Rng::new(11);
    for _ in 0..256 {
        // random finite bf16 grid point (mask out NaN/Inf exponents)
        let mut bits = rng.next_u64() as u16;
        if (bits & 0x7f80) == 0x7f80 {
            bits &= !0x4000;
        }
        let x = bf16_to_f32(bits);
        assert_eq!(round_to_nearest(x), bits, "RNE must fix grid point {bits:#06x}");
        for r in [0u16, 1, 0x7fff, 0x8000, 0xffff] {
            assert_eq!(
                f32_to_bf16_stochastic(x, r),
                bits,
                "stochastic draw {r:#06x} moved grid point {bits:#06x}"
            );
        }
    }
    // nearest-neighbour property on off-grid values
    prop::check(64, |rng| {
        let x = (rng.normal() as f32) * (0.01 + rng.uniform() as f32 * 100.0);
        let near = bf16_to_f32(round_to_nearest(x));
        let down = bf16_to_f32((x.to_bits() >> 16) as u16);
        let up = bf16_to_f32(((x.to_bits() >> 16) as u16).wrapping_add(1));
        let best = (near - x).abs();
        prop::assert_true(
            best <= (down - x).abs() && best <= (up - x).abs(),
            &format!("RNE of {x} chose {near}, not the nearest of {down}/{up}"),
        )
    });
}

// --------------------------------------------------------------- prodigy

/// Scalar re-derivation of the Prodigy recurrences, written against the
/// exemplar's formulas rather than `ProdigyState`'s code: the every-
/// `slice_p`-th subsample, `β3 = √β2`, `dlr = d·lr·√(1−β2^t)/(1−β1^t)`,
/// the `(d/d0)`-scaled numerator/denominator EMAs, and `growth_rate=∞`
/// monotone max. f64 accumulation, f32 state — like the real one.
struct Oracle {
    d: f32,
    d_num: f32,
    p0: Vec<f32>,
    s: Vec<f32>,
}

impl Oracle {
    fn new(numel: usize) -> Oracle {
        let k = numel.div_ceil(PRODIGY_SLICE_P);
        Oracle { d: PRODIGY_D0, d_num: 0.0, p0: vec![0.0; k], s: vec![0.0; k] }
    }

    fn update(&mut self, w: &[f32], g: &[f32], lr: f32, t: usize, hp: &OptHp) -> f32 {
        let sliced: Vec<usize> = (0..w.len()).step_by(PRODIGY_SLICE_P).collect();
        if t == 1 {
            for (k, &i) in sliced.iter().enumerate() {
                self.p0[k] = w[i];
            }
        }
        let d = self.d;
        let beta3 = (hp.beta2 as f64).sqrt();
        let dlr = (d * lr * prodigy_bc(hp, t)) as f64;
        let dd0 = (d / PRODIGY_D0) as f64;
        let mut dot = 0f64;
        for (k, &i) in sliced.iter().enumerate() {
            dot += g[i] as f64 * (self.p0[k] as f64 - w[i] as f64);
        }
        self.d_num = (beta3 * self.d_num as f64 + dd0 * dlr * dot) as f32;
        let mut denom = 0f64;
        for (k, &i) in sliced.iter().enumerate() {
            let sk = beta3 * self.s[k] as f64 + dd0 * dlr * g[i] as f64;
            self.s[k] = sk as f32;
            denom += sk.abs();
        }
        if denom > 0.0 {
            self.d = self.d.max((PRODIGY_D_COEF as f64 * self.d_num as f64 / denom) as f32);
        }
        d
    }
}

/// `ProdigyState::update` replays the oracle exactly (same f32 results
/// every step), D is monotone non-decreasing throughout, and on a
/// consistent descent trajectory it grows strictly above `d0`.
#[test]
fn prodigy_d_matches_scalar_oracle_and_never_decreases() {
    let hp = OptHp::prodigy();
    let numel = 37; // not a multiple of slice_p: exercises the ceil tail
    let mut rng = Rng::new(42);
    let mut w: Vec<f32> = (0..numel).map(|_| rng.normal_f32(0.5)).collect();
    let g_fixed: Vec<f32> = (0..numel).map(|_| rng.normal_f32(1.0)).collect();

    let mut state = ProdigyState::new(numel);
    let mut oracle = Oracle::new(numel);
    let lr = 0.05;
    let mut prev_d = state.d;
    for t in 1..=60 {
        // constant gradient for the first half (drives w away from p0 so
        // the numerator grows), random after (monotonicity under noise)
        let g: Vec<f32> = if t <= 30 {
            g_fixed.clone()
        } else {
            (0..numel).map(|_| rng.normal_f32(1.0)).collect()
        };
        let used = state.update(&w, &g, lr, t, &hp);
        let oracle_used = oracle.update(&w, &g, lr, t, &hp);
        assert_eq!(used, oracle_used, "step {t}: D used by the update diverged");
        assert_eq!(state.d, oracle.d, "step {t}: post-update D diverged");
        assert_eq!(state.d_num, oracle.d_num, "step {t}: d_numerator diverged");
        assert_eq!(state.s.data, oracle.s, "step {t}: denominator EMA diverged");
        assert!(state.d >= prev_d, "step {t}: D decreased {prev_d} -> {}", state.d);
        prev_d = state.d;
        // plain descent so the trajectory moves
        for (wi, gi) in w.iter_mut().zip(&g) {
            *wi -= used * lr * gi;
        }
    }
    assert_eq!(state.p0.data, oracle.p0, "p0 capture diverged");
    assert!(
        state.d > PRODIGY_D0,
        "D never adapted above d0 on a consistent descent: {}",
        state.d
    );
}

/// A zero gradient leaves D and its numerator untouched (the exemplar's
/// `denom == 0` skip) — no NaN from 0/0.
#[test]
fn prodigy_zero_gradient_is_a_noop() {
    let hp = OptHp::prodigy();
    let mut state = ProdigyState::new(16);
    let w = vec![1.0f32; 16];
    let g = vec![0.0f32; 16];
    for t in 1..=3 {
        let used = state.update(&w, &g, 0.1, t, &hp);
        assert_eq!(used, PRODIGY_D0);
    }
    assert_eq!(state.d, PRODIGY_D0);
    assert_eq!(state.d_num, 0.0);
    assert!(state.d.is_finite());
}

// -------------------------------------------------- effective-shape fold

/// Every 1D preset shape ([16] at l=4, [32] at l=4, [64] at l=8 — the
/// vectors of host-nano/tiny/small) folds to a valid factored shape:
/// the sides multiply back exactly, the short side is at least the
/// sketch rank, and the fold prefers the squarest split.
#[test]
fn every_1d_preset_shape_folds_exactly() {
    for (numel, l, want) in [(16usize, 4usize, [4usize, 4]), (32, 4, [4, 8]), (64, 8, [8, 8])] {
        let eff = effective_shape(numel, l)
            .unwrap_or_else(|| panic!("preset vector [{numel}] must fold at l={l}"));
        assert_eq!(eff, want, "[{numel}] at l={l}");
        assert_eq!(eff[0] * eff[1], numel, "fold must be exact, no padding");
        assert!(eff[0] >= l && eff[0] <= eff[1]);
    }
    // and the guards: primes and too-small factors don't fold
    assert_eq!(effective_shape(13, 4), None);
    assert_eq!(effective_shape(32, 5), None, "squarest side 4 < l=5");
}

/// The fold is a pure reshape: stepping a 1D parameter through a
/// factored variant restores its shape every step and produces data
/// bit-identical to stepping the same bytes as a native 2D parameter
/// of the effective shape.
#[test]
fn folded_1d_step_is_bit_identical_to_native_2d() {
    for (variant, numel, l) in [
        ("mlorc_adamw", 32usize, 4usize),
        ("mlorc_prodigy", 32, 4),
        ("mlorc_adamw_bf16", 64, 8),
    ] {
        let eff = effective_shape(numel, l).unwrap();
        let mut init = Rng::new(99);
        let data = init.gaussian_tensor(&[numel], 0.5).data;

        let mut w1 = Tensor::new(vec![numel], data.clone()).unwrap();
        let mut w2 = Tensor::new(vec![eff[0], eff[1]], data).unwrap();
        let mut st1 = OptState::for_variant(variant, &[numel], l).unwrap();
        let mut st2 = OptState::for_variant(variant, &[eff[0], eff[1]], l).unwrap();
        let (mut r1, mut r2) = (Rng::new(7), Rng::new(7));
        let (mut ws1, mut ws2) = (Workspace::new(), Workspace::new());
        let mut grad_rng = Rng::new(3);
        for t in 1..=4 {
            let g = grad_rng.gaussian_tensor(&[numel], 1.0);
            let g2 = Tensor::new(vec![eff[0], eff[1]], g.data.clone()).unwrap();
            st1.host_step(&mut w1, &g, 0.02, t, &mut r1, &mut ws1).unwrap();
            st2.host_step(&mut w2, &g2, 0.02, t, &mut r2, &mut ws2).unwrap();
            assert_eq!(w1.shape, vec![numel], "{variant}: shape not restored at step {t}");
            assert_eq!(
                w1.data, w2.data,
                "{variant}: folded [{numel}] diverged from native {eff:?} at step {t}"
            );
        }
    }
}

// ------------------------------------------------------------- modifiers

/// OrthoGrad: the projected gradient is orthogonal to the weight (to
/// 1e-6 of the norm product) and its norm matches the raw gradient's.
#[test]
fn orthograd_output_is_orthogonal_and_norm_preserving() {
    prop::check(64, |rng| {
        let m = rng.range(1, 12);
        let n = rng.range(1, 12);
        let w = rng.gaussian_tensor(&[m, n], 1.0);
        let g = rng.gaussian_tensor(&[m, n], 1.0);
        let out = orthogonalize_gradient(&w, &g);
        let dot: f64 = out.data.iter().zip(&w.data).map(|(a, b)| *a as f64 * *b as f64).sum();
        let nw: f64 = w.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let no: f64 = out.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let ng: f64 = g.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        prop::assert_lt(dot.abs(), 1e-6 * nw * no + 1e-20, "⟂ violated")?;
        prop::assert_close(no, ng, 1e-5 * ng + 1e-20, "norm not preserved")
    });
    // w = 0 is exact passthrough (the 1e-30 guards)
    let w = Tensor::zeros(&[3, 3]);
    let mut rng = Rng::new(1);
    let g = rng.gaussian_tensor(&[3, 3], 1.0);
    let out = orthogonalize_gradient(&w, &g);
    assert_eq!(out.data, g.data, "zero weight must pass the gradient through unchanged");
}

/// Grams: the step direction is `-sign(g)` elementwise, the magnitude
/// the base update's — checked against a plain MLorc-AdamW twin run on
/// the same Omega stream. A zero gradient component pins its weight.
#[test]
fn grams_update_sign_matches_gradient_with_base_magnitude() {
    let shape = [12usize, 8];
    let mut init = Rng::new(5);
    let w0 = init.gaussian_tensor(&shape, 0.7);
    let mut g = init.gaussian_tensor(&shape, 1.0);
    g.data[0] = 0.0; // sign(0) == 0: weight must not move

    let mut w_grams = w0.clone();
    let mut w_plain = w0.clone();
    let mut st_g = OptState::for_variant("mlorc_adamw_grams", &shape, 4).unwrap();
    let mut st_p = OptState::for_variant("mlorc_adamw", &shape, 4).unwrap();
    let (mut r1, mut r2) = (Rng::new(21), Rng::new(21));
    let mut ws = Workspace::new();
    st_g.host_step(&mut w_grams, &g, 0.05, 1, &mut r1, &mut ws).unwrap();
    st_p.host_step(&mut w_plain, &g, 0.05, 1, &mut r2, &mut ws).unwrap();

    assert_eq!(w_grams.data[0], w0.data[0], "zero-gradient weight moved");
    let mut mag_g = 0f64;
    let mut mag_p = 0f64;
    for i in 0..w0.len() {
        let dg = (w_grams.data[i] - w0.data[i]) as f64;
        let dp = (w_plain.data[i] - w0.data[i]) as f64;
        assert!(
            dg * g.data[i] as f64 <= 0.0,
            "elem {i}: grams step {dg} not opposite sign of g {}",
            g.data[i]
        );
        mag_g += dg.abs();
        mag_p += dp.abs();
    }
    assert!(mag_p > 0.0, "the base update must move");
    let rel = (mag_g - mag_p).abs() / mag_p;
    assert!(rel < 1e-5, "grams magnitude drifted from the base update: rel {rel}");
}

/// The atan2 apply `a·atan2(m̂, √v̂)` is bounded by `a·π/2 = 2`, odd in
/// `m̂`, and matches the eps-free ratio `m̂/√v̂` to 0.1% when the ratio
/// is small (where Adam spends most of training).
#[test]
fn atan2_apply_is_bounded_odd_and_matches_ratio_near_zero() {
    prop::check(128, |rng| {
        let v = (rng.uniform() as f32).max(1e-12) * 10.0;
        let m = rng.normal_f32(1.0) * v.sqrt() * 10.0; // ratios up to ~±30
        let step = ATAN2_SCALE * m.atan2(v.sqrt());
        prop::assert_lt(
            step.abs() as f64,
            ATAN2_SCALE as f64 * std::f64::consts::FRAC_PI_2 * (1.0 + 1e-6),
            "atan2 step must be bounded by a·π/2",
        )?;
        let neg = ATAN2_SCALE * (-m).atan2(v.sqrt());
        prop::assert_true(neg == -step, "atan2 apply must be odd in m̂")?;
        // near zero the apply is linear with slope a = 4/π ...
        let small = m * 1e-4;
        let lin = (ATAN2_SCALE * small / v.sqrt()) as f64;
        let near = (ATAN2_SCALE * small.atan2(v.sqrt())) as f64;
        prop::assert_close(near, lin, 1e-3 * lin.abs() + 1e-12, "near-zero slope")?;
        // ... and at m̂ = √v̂ it crosses the plain ratio exactly: a·atan(1) = 1
        let unit = (ATAN2_SCALE * v.sqrt().atan2(v.sqrt())) as f64;
        prop::assert_close(unit, 1.0, 1e-5, "a·atan2(x, x) must be 1")
    });
}

// ----------------------------------------------- determinism under load

/// The wave methods are host-only members of the batched step planner's
/// `Members` route: training must be bit-identical across thread
/// budgets (the suite itself also runs under CI budgets 1 and 8).
#[test]
fn wave_methods_bit_identical_across_thread_budgets() {
    for method in [Method::MlorcProdigy, Method::MlorcAdamWBf16] {
        let mut cfg = RunConfig::new("host-nano", method, TaskKind::MathChain, 4);
        cfg.peak_lr = 0.05;
        cfg.log_every = 0;
        cfg.seed = 17;
        let run = |budget: usize| {
            threads::with_budget(budget, || {
                let mut tr = HostTrainer::new(cfg.clone()).unwrap();
                for _ in 0..4 {
                    tr.train_step().unwrap();
                }
                tr.params.values.clone()
            })
        };
        let a = run(1);
        let b = run(8);
        for (j, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.data, y.data,
                "{}: param {j} differs between budgets 1 and 8",
                method.name()
            );
        }
    }
}
