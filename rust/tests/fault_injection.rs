//! Fault-injection acceptance suite (PR 6): torn checkpoint pointers
//! fall back to the previous intact snapshot bit-identically, injected
//! IO faults are retried with backoff until the budget is exhausted,
//! two concurrent schedulers drain one spool exactly once, and
//! `mlorc fsck` detects + repairs corrupt snapshots and orphaned
//! scratch dirs.
//!
//! Failpoints are process-global, so every test here serializes on
//! [`FP_LOCK`] and starts from a cleared registry — even the tests that
//! arm nothing, since they must not run concurrently with a test that
//! does.

use std::path::PathBuf;
use std::process::Command;
use std::sync::Mutex;

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::linalg::threads;
use mlorc::obs;
use mlorc::obs::registry::CKPT_BACKPRESSURE_STALLS;
use mlorc::serve::{
    aggregate, fsck, render_report, serve, Engine, HostTrainer, JobSpec, ServeOpts, Spool,
    CRASH_EXIT_CODE,
};
use mlorc::tensor::Tensor;
use mlorc::util::fsutil::failpoints;

static FP_LOCK: Mutex<()> = Mutex::new(());

fn fp_guard() -> std::sync::MutexGuard<'static, ()> {
    let g = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoints::clear();
    g
}

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mlorc_fault_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn job_cfg(method: Method, seed: u64, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::new("host-nano", method, TaskKind::MathChain, steps);
    cfg.peak_lr = 0.03;
    cfg.log_every = 0;
    cfg.seed = seed;
    cfg
}

fn spec(id: &str, cfg: RunConfig, checkpoint_every: usize) -> JobSpec {
    JobSpec {
        id: id.to_string(),
        engine: Engine::Host,
        checkpoint_every,
        priority: 0,
        attempts: Vec::new(),
        not_before_unix_ms: 0,
        cfg,
    }
}

fn solo_params(cfg: &RunConfig, budget: usize) -> Vec<Tensor> {
    threads::with_budget(budget, || {
        let mut tr = HostTrainer::new(cfg.clone()).unwrap();
        for _ in 0..cfg.steps {
            tr.train_step().unwrap();
        }
        tr.params.values.clone()
    })
}

/// Final params of a finished job, read back through its checkpoint.
fn final_params(spool: &Spool, id: &str) -> Vec<Tensor> {
    let spec = spool.load_spec("done", id).unwrap();
    let mut tr = HostTrainer::new(spec.cfg.clone()).unwrap();
    tr.resume_from(&spool.checkpoint_root(id)).unwrap();
    assert_eq!(tr.step_count(), spec.cfg.steps);
    tr.params.values.clone()
}

fn flip_byte(path: &std::path::Path) {
    let mut bytes = std::fs::read(path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x55;
    std::fs::write(path, bytes).unwrap();
}

/// Acceptance #1: a torn `LATEST` plus a corrupt newest snapshot resume
/// from the previous intact snapshot, and the completed run is
/// bit-identical to one that was never interrupted.
#[test]
fn torn_latest_resumes_from_previous_intact_snapshot_bit_identical() {
    let _g = fp_guard();
    let root = tmp("torn");
    let spool = Spool::open(&root).unwrap();
    let cfg = job_cfg(Method::MlorcAdamW, 7, 12);
    // uninterrupted reference at the slice a solo serve would use
    let reference = solo_params(&cfg, threads::budget().max(1));

    spool.submit(&spec("job001_torn", cfg.clone(), 5)).unwrap();
    // simulate a crashed worker: 10 steps, cadence snapshots at 5 and 10,
    // with the LATEST flip of the second snapshot torn mid-write
    let claimed = spool.claim_next().unwrap().unwrap();
    let ckpt_root = spool.checkpoint_root(&claimed.id);
    let mut tr = HostTrainer::new(claimed.cfg.clone()).unwrap();
    for _ in 0..5 {
        tr.train_step().unwrap();
    }
    tr.save_checkpoint(&ckpt_root).unwrap();
    for _ in 0..5 {
        tr.train_step().unwrap();
    }
    failpoints::arm("latest_write:torn@1").unwrap();
    tr.save_checkpoint(&ckpt_root).unwrap();
    failpoints::clear();
    drop(tr);
    // the torn LATEST names a half-written garbage target; additionally
    // corrupt the newest snapshot so the fallback has to reach step-5
    let latest = std::fs::read_to_string(ckpt_root.join("LATEST")).unwrap();
    assert_ne!(latest.trim(), "step-00000010", "LATEST should be torn");
    flip_byte(&ckpt_root.join("step-00000010").join("params.rten"));

    // restart: recovery re-queues the lease-less job, resume falls back
    // to step-5 and the job completes
    let opts = ServeOpts {
        jobs: 1,
        drain: true,
        poll_ms: 10,
        lease_timeout_ms: 0,
        ..Default::default()
    };
    let summary = serve(&spool, &opts).unwrap();
    assert_eq!(summary.recovered, 1);
    assert_eq!(summary.done, 1);
    assert_eq!(summary.failed, 0);

    let served = final_params(&spool, "job001_torn");
    assert_eq!(served.len(), reference.len());
    for (j, (a, b)) in served.iter().zip(&reference).enumerate() {
        assert_eq!(a.data, b.data, "param {j} != uninterrupted run");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Same torn-`LATEST` fallback, for a method carrying a bf16 weight
/// plane: the `w16` tensor in the step-5 snapshot must restore the
/// stochastic-rounding state exactly, or the continuation diverges from
/// the uninterrupted run at the first post-resume store.
#[test]
fn torn_latest_with_bf16_plane_resumes_bit_identical() {
    let _g = fp_guard();
    let root = tmp("torn_bf16");
    let spool = Spool::open(&root).unwrap();
    let cfg = job_cfg(Method::MlorcAdamWBf16, 21, 12);
    let reference = solo_params(&cfg, threads::budget().max(1));

    spool.submit(&spec("job001_torn16", cfg.clone(), 5)).unwrap();
    let claimed = spool.claim_next().unwrap().unwrap();
    let ckpt_root = spool.checkpoint_root(&claimed.id);
    let mut tr = HostTrainer::new(claimed.cfg.clone()).unwrap();
    for _ in 0..5 {
        tr.train_step().unwrap();
    }
    tr.save_checkpoint(&ckpt_root).unwrap();
    for _ in 0..5 {
        tr.train_step().unwrap();
    }
    failpoints::arm("latest_write:torn@1").unwrap();
    tr.save_checkpoint(&ckpt_root).unwrap();
    failpoints::clear();
    drop(tr);
    flip_byte(&ckpt_root.join("step-00000010").join("params.rten"));

    let opts = ServeOpts {
        jobs: 1,
        drain: true,
        poll_ms: 10,
        lease_timeout_ms: 0,
        ..Default::default()
    };
    let summary = serve(&spool, &opts).unwrap();
    assert_eq!(summary.recovered, 1);
    assert_eq!(summary.done, 1);
    assert_eq!(summary.failed, 0);

    let served = final_params(&spool, "job001_torn16");
    for (j, (a, b)) in served.iter().zip(&reference).enumerate() {
        assert_eq!(a.data, b.data, "param {j} != uninterrupted bf16 run");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Acceptance #2: a job failed by an injected fault is retried (with the
/// attempt recorded) and completes.
#[test]
fn injected_fault_is_retried_and_job_completes() {
    let _g = fp_guard();
    let root = tmp("retry");
    let spool = Spool::open(&root).unwrap();
    spool.submit(&spec("job001_retry", job_cfg(Method::MlorcLion, 3, 4), 2)).unwrap();
    // the first checkpoint-file write fails as if the disk were full;
    // everything after succeeds
    failpoints::arm("ckpt_write:enospc@1").unwrap();
    let opts = ServeOpts {
        jobs: 1,
        drain: true,
        poll_ms: 10,
        max_retries: 2,
        retry_backoff_ms: 10,
        ..Default::default()
    };
    let summary = serve(&spool, &opts).unwrap();
    failpoints::clear();
    assert_eq!(summary.done, 1, "job must complete after the retry");
    assert_eq!(summary.failed, 0);
    assert_eq!(summary.retried, 1);

    let done_spec = spool.load_spec("done", "job001_retry").unwrap();
    assert_eq!(done_spec.attempts.len(), 1, "the failed run must be recorded");
    assert!(
        done_spec.attempts[0].error.contains("ENOSPC"),
        "attempt error should carry the injected fault: {}",
        done_spec.attempts[0].error
    );
    // the audit trail shows exactly two claims: original + retry
    let log = std::fs::read_to_string(spool.work_dir("job001_retry").join("claims.log")).unwrap();
    assert_eq!(log.lines().count(), 2, "claims.log:\n{log}");
    // `mlorc status` surfaces the attempt history
    let rows = aggregate(&spool).unwrap();
    assert_eq!(rows[0].state, "done");
    assert_eq!(rows[0].attempts.len(), 1);
    std::fs::remove_dir_all(&root).unwrap();
}

/// Acceptance #3: two concurrent schedulers on one spool drain a 6-job
/// backlog with every job run exactly once, and per-job final params
/// bit-identical to solo runs.
#[test]
fn two_schedulers_drain_exactly_once_and_match_solo() {
    let _g = fp_guard();
    let root = tmp("dual");
    let spool = Spool::open(&root).unwrap();
    let methods = [Method::MlorcAdamW, Method::MlorcLion, Method::MlorcSgdM];
    let mut ids = Vec::new();
    for i in 0..6usize {
        let m = methods[i % methods.len()];
        let id = format!("job{:03}_{}", i + 1, m.name());
        spool.submit(&spec(&id, job_cfg(m, 40 + i as u64, 6), 3)).unwrap();
        ids.push((id, m, 40 + i as u64));
    }

    let opts = || ServeOpts {
        jobs: 2,
        drain: true,
        poll_ms: 10,
        lease_timeout_ms: 60_000,
        ..Default::default()
    };
    let (s1, s2) = std::thread::scope(|s| {
        let a = s.spawn(|| {
            let spool = Spool::open(&root).unwrap();
            serve(&spool, &opts()).unwrap()
        });
        let b = s.spawn(|| {
            let spool = Spool::open(&root).unwrap();
            serve(&spool, &opts()).unwrap()
        });
        (a.join().unwrap(), b.join().unwrap())
    });
    assert_eq!(s1.done + s2.done, 6, "schedulers: {s1:?} / {s2:?}");
    assert_eq!(s1.failed + s2.failed, 0);
    assert_eq!(spool.jobs_in("done").unwrap().len(), 6);
    assert!(spool.jobs_in("queue").unwrap().is_empty());
    assert!(spool.jobs_in("running").unwrap().is_empty());

    // exactly once: one claim per job across both schedulers
    for (id, _, _) in &ids {
        let log = std::fs::read_to_string(spool.work_dir(id).join("claims.log")).unwrap();
        assert_eq!(log.lines().count(), 1, "job {id} claimed more than once:\n{log}");
    }
    // bit-identical to a solo run at the same per-job thread slice
    let slice = (threads::budget() / 2).max(1);
    for (id, m, seed) in &ids {
        let served = final_params(&spool, id);
        let solo = solo_params(&job_cfg(*m, *seed, 6), slice);
        for (j, (a, b)) in served.iter().zip(&solo).enumerate() {
            assert_eq!(a.data, b.data, "job {id} param {j} != solo run");
        }
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Acceptance #4: once `--max-retries` is exhausted the job is
/// quarantined in `failed/` with its full attempt history visible to
/// `mlorc status --json`.
#[test]
fn retry_budget_exhaustion_quarantines_with_attempt_history() {
    let _g = fp_guard();
    let root = tmp("exhaust");
    let spool = Spool::open(&root).unwrap();
    spool.submit(&spec("job001_doomed", job_cfg(Method::MlorcAdamW, 9, 4), 2)).unwrap();
    // every checkpoint-file write fails: the job can never finish
    failpoints::arm("ckpt_write:enospc@1+").unwrap();
    let opts = ServeOpts {
        jobs: 1,
        drain: true,
        poll_ms: 10,
        max_retries: 2,
        retry_backoff_ms: 5,
        ..Default::default()
    };
    let summary = serve(&spool, &opts).unwrap();
    failpoints::clear();
    assert_eq!(summary.done, 0);
    assert_eq!(summary.failed, 1);
    assert_eq!(summary.retried, 2, "max_retries=2 means two re-queues before quarantine");
    assert_eq!(spool.jobs_in("failed").unwrap(), vec!["job001_doomed"]);

    // original run + 2 retries = 3 recorded attempts, in the spec and
    // through the status aggregation (what `mlorc status --json` prints)
    let failed_spec = spool.load_spec("failed", "job001_doomed").unwrap();
    assert_eq!(failed_spec.attempts.len(), 3);
    assert!(failed_spec.attempts[0].backoff_ms > 0);
    assert_eq!(failed_spec.attempts[2].backoff_ms, 0, "terminal attempt has no backoff");
    let rows = aggregate(&spool).unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].state, "failed");
    assert_eq!(rows[0].attempts.len(), 3);
    let json = rows[0].to_json().to_string_compact();
    assert!(json.contains("\"attempts\""), "{json}");
    std::fs::remove_dir_all(&root).unwrap();
}

/// Satellite: `mlorc fsck` flags corrupt snapshots, dangling LATEST
/// pointers and orphaned work dirs; `--repair` drops the spool back to
/// its last intact state.
#[test]
fn fsck_detects_and_repairs_corruption_and_orphans() {
    let _g = fp_guard();
    let root = tmp("fsck");
    let spool = Spool::open(&root).unwrap();
    spool.submit(&spec("job001_ok", job_cfg(Method::MlorcLion, 5, 12), 5)).unwrap();
    let opts = ServeOpts { jobs: 1, drain: true, poll_ms: 10, ..Default::default() };
    let summary = serve(&spool, &opts).unwrap();
    assert_eq!(summary.done, 1);

    // clean spool: fsck passes
    let report = fsck(&spool, false).unwrap();
    assert!(report.clean(), "{}", render_report(&report));
    assert_eq!(report.jobs_checked, 1);
    assert!(report.snapshots_ok >= 2, "rotation keeps two snapshots");

    // corrupt the newest snapshot (LATEST target) + plant an orphan
    let ckpt_root = spool.checkpoint_root("job001_ok");
    flip_byte(&ckpt_root.join("step-00000012").join("params.rten"));
    std::fs::create_dir_all(spool.work_dir("ghost_job")).unwrap();
    std::fs::write(spool.work_dir("ghost_job").join("scratch.bin"), b"junk").unwrap();

    let report = fsck(&spool, false).unwrap();
    assert!(!report.clean());
    assert!(
        report.problems.iter().any(|p| p.snapshot == "step-00000012"),
        "{}",
        render_report(&report)
    );
    assert!(report.problems.iter().any(|p| p.snapshot == "LATEST"));
    assert_eq!(report.orphans, vec!["ghost_job"]);

    // repair drops the corrupt snapshot, repoints LATEST to the previous
    // intact one, and reaps the orphan
    let repaired = fsck(&spool, true).unwrap();
    assert!(repaired.clean(), "{}", render_report(&repaired));
    assert!(!ckpt_root.join("step-00000012").exists());
    assert_eq!(
        std::fs::read_to_string(ckpt_root.join("LATEST")).unwrap().trim(),
        "step-00000010"
    );
    assert!(!spool.work_dir("ghost_job").exists());
    let recheck = fsck(&spool, false).unwrap();
    assert!(recheck.clean(), "{}", render_report(&recheck));

    // the repaired root resumes from the surviving snapshot
    let done_spec = spool.load_spec("done", "job001_ok").unwrap();
    let mut tr = HostTrainer::new(done_spec.cfg).unwrap();
    assert_eq!(tr.resume_from(&ckpt_root).unwrap(), 10);
    std::fs::remove_dir_all(&root).unwrap();
}

/// Async tentpole #1: kill -9 on the *writer thread* mid-commit. With
/// one job at cadence 5 a snapshot is exactly four checkpoint-file
/// writes, so `ckpt_write:kill@7` dies on the manifest of the second
/// snapshot — after its tensors landed, before its `meta.json` commit
/// marker. The restart must fall back to the previous intact snapshot
/// and finish bit-identically to an uninterrupted run.
#[test]
fn kill_mid_async_commit_resumes_from_previous_snapshot_bit_identical() {
    let _g = fp_guard();
    let root = tmp("killcommit");
    let spool = Spool::open(&root).unwrap();
    let cfg = job_cfg(Method::MlorcAdamW, 21, 12);
    let reference = solo_params(&cfg, threads::budget().max(1));
    spool.submit(&spec("job001_kill", cfg, 5)).unwrap();

    // the kill exits the whole process, so scheduler 1 is the real
    // `mlorc serve` binary with the failpoint armed via its environment
    let status = Command::new(env!("CARGO_BIN_EXE_mlorc"))
        .arg("serve")
        .arg("--spool")
        .arg(&root)
        .arg("--jobs")
        .arg("1")
        .arg("--drain")
        .arg("--poll-ms")
        .arg("10")
        .arg("--lease-timeout-ms")
        .arg("500")
        .env("MLORC_FAILPOINT", "ckpt_write:kill@7")
        .env_remove("MLORC_NO_OBS")
        .status()
        .expect("spawn mlorc serve");
    assert_eq!(
        status.code(),
        Some(CRASH_EXIT_CODE),
        "writer-thread kill must take down the process with the crash exit code"
    );

    // mid-commit wreckage: step-10 exists but never got its commit
    // marker, and LATEST still names the first snapshot
    let ckpt_root = spool.checkpoint_root("job001_kill");
    assert_eq!(
        std::fs::read_to_string(ckpt_root.join("LATEST")).unwrap().trim(),
        "step-00000005",
        "LATEST must not move until the full snapshot is on disk"
    );
    assert!(
        !ckpt_root.join("step-00000010").join("meta.json").exists(),
        "the torn snapshot must have no commit marker"
    );

    // restart: the dead scheduler's lease expires, the job resumes from
    // step-5 and completes exactly as if it had never crashed
    let opts = ServeOpts {
        jobs: 1,
        drain: true,
        poll_ms: 10,
        lease_timeout_ms: 500,
        ..Default::default()
    };
    let summary = serve(&spool, &opts).unwrap();
    assert_eq!(summary.done, 1);
    assert_eq!(summary.failed, 0);
    let served = final_params(&spool, "job001_kill");
    assert_eq!(served.len(), reference.len());
    for (j, (a, b)) in served.iter().zip(&reference).enumerate() {
        assert_eq!(a.data, b.data, "param {j} != uninterrupted run");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Async tentpole #2: a fault on the writer thread must not vanish with
/// the thread. `ckpt_write:enospc@5` fails the first file of the second
/// snapshot (cadence 2, steps 6); the error surfaces at the terminal
/// join, fails the attempt with the injected ENOSPC recorded, and the
/// retry resumes from the first intact snapshot and completes.
#[test]
fn writer_thread_fault_is_surfaced_recorded_and_retried() {
    let _g = fp_guard();
    let root = tmp("asyncspc");
    let spool = Spool::open(&root).unwrap();
    spool.submit(&spec("job001_async", job_cfg(Method::MlorcLion, 13, 6), 2)).unwrap();
    failpoints::arm("ckpt_write:enospc@5").unwrap();
    let opts = ServeOpts {
        jobs: 1,
        drain: true,
        poll_ms: 10,
        max_retries: 2,
        retry_backoff_ms: 10,
        ..Default::default()
    };
    let summary = serve(&spool, &opts).unwrap();
    failpoints::clear();
    assert_eq!(summary.done, 1, "job must complete after the retry");
    assert_eq!(summary.failed, 0);
    assert_eq!(summary.retried, 1);

    let done_spec = spool.load_spec("done", "job001_async").unwrap();
    assert_eq!(done_spec.attempts.len(), 1, "the writer-thread failure must be recorded");
    assert!(
        done_spec.attempts[0].error.contains("ENOSPC"),
        "attempt error should carry the injected fault: {}",
        done_spec.attempts[0].error
    );
    std::fs::remove_dir_all(&root).unwrap();
}

/// Async tentpole #3: backpressure. With every checkpoint-file write
/// slowed 25ms and a cadence of 1, both scratch buffers are in flight by
/// step 3 and the step loop must stall at least once — and the run's
/// weights AND its on-disk snapshots stay byte-identical to the same job
/// under `--checkpoint-sync`.
#[test]
fn backpressure_stalls_and_stays_bit_identical_to_sync() {
    let _g = fp_guard();
    obs::force_enabled(true);
    let root_async = tmp("bpasync");
    let root_sync = tmp("bpsync");

    let spool = Spool::open(&root_async).unwrap();
    spool.submit(&spec("job001_bp", job_cfg(Method::MlorcSgdM, 17, 6), 1)).unwrap();
    failpoints::arm("ckpt_write:slow@1+").unwrap();
    let stalls_before = CKPT_BACKPRESSURE_STALLS.get();
    let opts = ServeOpts { jobs: 1, drain: true, poll_ms: 10, ..Default::default() };
    let summary = serve(&spool, &opts).unwrap();
    failpoints::clear();
    assert_eq!(summary.done, 1);
    assert!(
        CKPT_BACKPRESSURE_STALLS.get() > stalls_before,
        "cadence 1 with slowed commits must stall on the scratch buffers"
    );

    let spool_sync = Spool::open(&root_sync).unwrap();
    spool_sync.submit(&spec("job001_bp", job_cfg(Method::MlorcSgdM, 17, 6), 1)).unwrap();
    let sync_opts = ServeOpts {
        jobs: 1,
        drain: true,
        poll_ms: 10,
        checkpoint_sync: true,
        ..Default::default()
    };
    let summary = serve(&spool_sync, &sync_opts).unwrap();
    assert_eq!(summary.done, 1);

    let served = final_params(&spool, "job001_bp");
    let served_sync = final_params(&spool_sync, "job001_bp");
    for (j, (a, b)) in served.iter().zip(&served_sync).enumerate() {
        assert_eq!(a.data, b.data, "param {j}: async != --checkpoint-sync");
    }
    // rotation keeps the last two snapshots; the async-written bytes on
    // disk must match the sync writer's file for file
    for snap in ["step-00000005", "step-00000006"] {
        for file in ["params.rten", "opt_state.rten", "manifest.json", "meta.json"] {
            let a = std::fs::read(spool.checkpoint_root("job001_bp").join(snap).join(file))
                .unwrap_or_else(|e| panic!("async {snap}/{file}: {e}"));
            let b = std::fs::read(spool_sync.checkpoint_root("job001_bp").join(snap).join(file))
                .unwrap_or_else(|e| panic!("sync {snap}/{file}: {e}"));
            assert_eq!(a, b, "{snap}/{file} differs between async and sync writers");
        }
    }
    std::fs::remove_dir_all(&root_async).unwrap();
    std::fs::remove_dir_all(&root_sync).unwrap();
}
