//! Integration: artifacts -> PJRT -> numerics. Requires `make artifacts`
//! (nano preset); every test no-ops gracefully when artifacts are missing
//! so pure-rust CI still passes.

use mlorc::runtime::{HostValue, Manifest, Runtime};
use mlorc::tensor::{Tensor, TensorI32};
use mlorc::util::fsutil;

fn setup() -> Option<(Manifest, Runtime)> {
    let dir = fsutil::artifacts_dir().ok()?;
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Manifest::load(&dir).ok()?;
    let rt = Runtime::cpu(&dir).unwrap();
    Some((manifest, rt))
}

#[test]
fn adamw_step_matches_hand_computation() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let spec = preset.opt_step("adamw", "64x64").unwrap();
    let shape = [64usize, 64];
    let w = Tensor::full(&shape, 1.0);
    let g = Tensor::full(&shape, 0.5);
    let m = Tensor::zeros(&shape);
    let v = Tensor::zeros(&shape);
    let (lr, c1, c2) = (0.1f32, 10.0f32, 1000.0f32);
    let outs = rt
        .run(
            spec,
            &[
                w.clone().into(),
                g.clone().into(),
                m.into(),
                v.into(),
                HostValue::scalar_f32(lr),
                HostValue::scalar_f32(c1),
                HostValue::scalar_f32(c2),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 3);
    // beta1=0.9, beta2=0.999 (manifest-recorded defaults)
    let beta1 = spec.hparam_f32("beta1", f32::NAN);
    let beta2 = spec.hparam_f32("beta2", f32::NAN);
    let eps = spec.hparam_f32("eps", f32::NAN);
    assert_eq!(beta1, 0.9);
    let m2 = outs[1].as_f32().unwrap();
    let v2 = outs[2].as_f32().unwrap();
    let w2 = outs[0].as_f32().unwrap();
    let want_m = (1.0 - beta1) * 0.5;
    let want_v = (1.0 - beta2) * 0.25;
    assert!((m2.data[0] - want_m).abs() < 1e-7, "{} vs {want_m}", m2.data[0]);
    // (1 - beta2) is baked in f64 python-side but recomputed in f32 here
    assert!((v2.data[0] - want_v).abs() < 1e-8);
    let want_w = 1.0 - lr * (want_m * c1) / ((want_v * c2).sqrt() + eps);
    assert!((w2.data[0] - want_w).abs() < 1e-5, "{} vs {want_w}", w2.data[0]);
    // all entries identical by symmetry
    assert!(w2.data.iter().all(|x| (x - w2.data[0]).abs() < 1e-6));
}

#[test]
fn mlorc_adamw_step_runs_and_preserves_invariants() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let spec = preset.opt_step("mlorc_adamw", "64x256").unwrap();
    let (m, n, l) = (64usize, 256usize, spec.l);
    assert_eq!(spec.rank, 4);
    let mut rng = mlorc::linalg::Rng::new(0);
    let w = rng.gaussian_tensor(&[m, n], 0.1);
    let g = rng.gaussian_tensor(&[m, n], 0.1);
    let outs = rt
        .run(
            spec,
            &[
                w.clone().into(),
                g.clone().into(),
                Tensor::zeros(&[m, l]).into(),
                Tensor::zeros(&[l, n]).into(),
                Tensor::zeros(&[m, l]).into(),
                Tensor::zeros(&[l, n]).into(),
                rng.gaussian_tensor(&[n, l], 1.0).into(),
                rng.gaussian_tensor(&[n, l], 1.0).into(),
                HostValue::scalar_f32(1e-3),
                HostValue::scalar_f32(5.0),
                HostValue::scalar_f32(1000.0),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 5);
    let w2 = outs[0].as_f32().unwrap();
    assert_eq!(w2.shape, vec![m, n]);
    assert!(w2.data.iter().all(|x| x.is_finite()));
    // First step from zero state: m_t = (1-beta1) g, which is full rank —
    // but the *reconstruction* QB must still be a contraction of m_t.
    let mq = outs[1].as_f32().unwrap();
    let mb = outs[2].as_f32().unwrap();
    assert_eq!(mq.shape, vec![m, l]);
    assert_eq!(mb.shape, vec![l, n]);
    let recon = mlorc::linalg::matmul(mq, mb);
    let beta1 = spec.hparam_f32("beta1", 0.8);
    let mt = g.map(|x| (1.0 - beta1) * x);
    assert!(recon.norm_fro() <= mt.norm_fro() * 1.0001);
    // v factors reconstruct to a nonnegative-dominant matrix
    let vq = outs[3].as_f32().unwrap();
    let vb = outs[4].as_f32().unwrap();
    let vrec = mlorc::linalg::matmul(vq, vb);
    assert!(vrec.data.iter().all(|x| x.is_finite()));
    // and the weight moved
    assert!(w2.rel_err(&w) > 0.0);
}

#[test]
fn nano_fwd_bwd_loss_is_log_vocab_at_init() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let dims = preset.model;
    let spec = preset.graph("fwd_bwd").unwrap();
    let mut rng = mlorc::linalg::Rng::new(42);

    // init params per the documented scheme
    let mut inputs: Vec<HostValue> = Vec::new();
    let toks: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|_| rng.range(1, dims.vocab) as i32)
        .collect();
    let mut tgts = toks.clone();
    tgts.rotate_left(1);
    inputs.push(TensorI32::new(vec![dims.batch, dims.seq], toks).unwrap().into());
    inputs.push(TensorI32::new(vec![dims.batch, dims.seq], tgts).unwrap().into());
    for p in preset.lm_params() {
        let t = if p.kind == "vector" {
            if p.name.ends_with("_g") {
                Tensor::full(&p.shape, 1.0)
            } else {
                Tensor::zeros(&p.shape)
            }
        } else {
            rng.gaussian_tensor(&p.shape, 0.02)
        };
        inputs.push(t.into());
    }
    let outs = rt.run(spec, &inputs).unwrap();
    assert_eq!(outs.len(), preset.lm_params().len() + 1);
    let loss = outs[0].scalar().unwrap();
    // fresh random model ≈ uniform over vocab
    assert!(
        (loss - (dims.vocab as f32).ln()).abs() < 1.0,
        "loss {loss} vs ln(V) {}",
        (dims.vocab as f32).ln()
    );
    // gradient shapes match the manifest param table
    for (p, gout) in preset.lm_params().iter().zip(&outs[1..]) {
        assert_eq!(gout.as_f32().unwrap().shape, p.shape, "grad shape of {}", p.name);
    }
}

#[test]
fn eval_graph_reports_correct_mask() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let dims = preset.model;
    let spec = preset.graph("eval").unwrap();
    let mut rng = mlorc::linalg::Rng::new(1);
    let mut inputs: Vec<HostValue> = Vec::new();
    let toks: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|_| rng.range(1, dims.vocab) as i32)
        .collect();
    // all targets padded: correct mask must be all zeros
    let tgts = vec![-1i32; dims.batch * dims.seq];
    inputs.push(TensorI32::new(vec![dims.batch, dims.seq], toks).unwrap().into());
    inputs.push(TensorI32::new(vec![dims.batch, dims.seq], tgts).unwrap().into());
    for p in preset.lm_params() {
        let t = if p.kind == "vector" {
            if p.name.ends_with("_g") { Tensor::full(&p.shape, 1.0) } else { Tensor::zeros(&p.shape) }
        } else {
            rng.gaussian_tensor(&p.shape, 0.02)
        };
        inputs.push(t.into());
    }
    let outs = rt.run(spec, &inputs).unwrap();
    let mask = outs[1].as_f32().unwrap();
    assert_eq!(mask.shape, vec![dims.batch, dims.seq]);
    assert!(mask.data.iter().all(|x| *x == 0.0));
}

#[test]
fn input_shape_mismatch_is_rejected() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let spec = preset.opt_step("adamw", "64").unwrap();
    let bad = vec![
        HostValue::F32(Tensor::zeros(&[65])), // wrong shape
        HostValue::F32(Tensor::zeros(&[64])),
        HostValue::F32(Tensor::zeros(&[64])),
        HostValue::F32(Tensor::zeros(&[64])),
        HostValue::scalar_f32(0.1),
        HostValue::scalar_f32(1.0),
        HostValue::scalar_f32(1.0),
    ];
    let err = rt.run(spec, &bad).unwrap_err().to_string();
    assert!(err.contains("expects shape"), "{err}");
}
