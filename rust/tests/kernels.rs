//! Property tests for the blocked/threaded linalg kernels and the
//! factored recompression fast path, against the scalar reference tier on
//! adversarial shapes: 1×n, m×1, tall-skinny, wide-flat, and sizes that
//! are not multiples of the register tile or k-panel.

use mlorc::linalg::{
    matmul, matmul_a_bt, matmul_at_b, rsvd_qb, rsvd_qb_factored, scalar_matmul,
    scalar_matmul_a_bt, scalar_matmul_at_b, threads, Rng, Workspace,
};
use mlorc::tensor::Tensor;
use mlorc::testing::prop;

/// Adversarial dim: degenerate and tile-straddling sizes, plus random.
fn adversarial_dim(rng: &mut Rng) -> usize {
    match rng.below(8) {
        0 => 1,
        1 => 2,
        2 => 3,   // below the 4-row register tile
        3 => 5,   // straddles it
        4 => 15,  // just under SMALL_N
        5 => 17,  // just over SMALL_N
        6 => 63,  // odd multi-tile
        _ => rng.range(1, 80),
    }
}

#[test]
fn blocked_matmul_matches_scalar_on_adversarial_shapes() {
    prop::check(64, |rng| {
        let (m, k, n) = (adversarial_dim(rng), adversarial_dim(rng), adversarial_dim(rng));
        let a = rng.gaussian_tensor(&[m, k], 1.0);
        let b = rng.gaussian_tensor(&[k, n], 1.0);
        let fast = matmul(&a, &b);
        let slow = scalar_matmul(&a, &b);
        prop::assert_lt(
            fast.max_abs_diff(&slow) as f64,
            1e-4 * (k as f64).sqrt().max(1.0),
            &format!("matmul ({m},{k},{n})"),
        )
    });
}

#[test]
fn blocked_at_b_and_a_bt_match_scalar() {
    prop::check(64, |rng| {
        let (m, k, n) = (adversarial_dim(rng), adversarial_dim(rng), adversarial_dim(rng));
        let a = rng.gaussian_tensor(&[m, k], 1.0);
        let b = rng.gaussian_tensor(&[m, n], 1.0);
        let fast = matmul_at_b(&a, &b);
        let slow = scalar_matmul_at_b(&a, &b);
        prop::assert_lt(
            fast.max_abs_diff(&slow) as f64,
            1e-4 * (m as f64).sqrt().max(1.0),
            &format!("at_b ({m},{k},{n})"),
        )?;
        let bt = rng.gaussian_tensor(&[n, k], 1.0);
        let fast = matmul_a_bt(&a, &bt);
        let slow = scalar_matmul_a_bt(&a, &bt);
        prop::assert_lt(
            fast.max_abs_diff(&slow) as f64,
            1e-4 * (k as f64).sqrt().max(1.0),
            &format!("a_bt ({m},{k},{n})"),
        )
    });
}

#[test]
fn threaded_kernels_are_bit_deterministic() {
    // Results must be bit-identical whether banding threads are used or
    // not — this is what makes parallel host stepping reproducible.
    prop::check(16, |rng| {
        let (m, k, n) = (rng.range(30, 130), rng.range(1, 70), rng.range(1, 70));
        let a = rng.gaussian_tensor(&[m, k], 1.0);
        let b = rng.gaussian_tensor(&[k, n], 1.0);
        let threaded = matmul(&a, &b);
        let serial = threads::serial(|| matmul(&a, &b));
        prop::assert_true(threaded.data == serial.data, "matmul banding changed bits")?;

        let b2 = rng.gaussian_tensor(&[m, n], 1.0);
        let t2 = matmul_at_b(&a, &b2);
        let s2 = threads::serial(|| matmul_at_b(&a, &b2));
        prop::assert_true(t2.data == s2.data, "at_b banding changed bits")
    });
}

#[test]
fn gemm_variants_bit_identical_across_budgets() {
    // Emulates MLORC_THREADS ∈ {1, 2, 3, 8} (the env var is latched once
    // per process; `threads::with_budget` is the same knob per thread):
    // every band plan must produce the same bits, including from inside a
    // nested `threads::serial` scope. Shapes sized so the 64k-madds/band
    // threshold actually splits work at budget >= 2.
    let mut rng = Rng::new(77);
    let a = rng.gaussian_tensor(&[137, 61], 1.0);
    let b = rng.gaussian_tensor(&[61, 45], 1.0);
    let b2 = rng.gaussian_tensor(&[137, 45], 1.0);
    let bt = rng.gaussian_tensor(&[45, 61], 1.0);

    let base_nn = threads::with_budget(1, || matmul(&a, &b));
    let base_tn = threads::with_budget(1, || matmul_at_b(&a, &b2));
    let base_nt = threads::with_budget(1, || matmul_a_bt(&a, &bt));
    for budget in [2usize, 3, 8] {
        threads::with_budget(budget, || {
            assert_eq!(matmul(&a, &b).data, base_nn.data, "nn budget {budget}");
            assert_eq!(matmul_at_b(&a, &b2).data, base_tn.data, "tn budget {budget}");
            assert_eq!(matmul_a_bt(&a, &bt).data, base_nt.data, "nt budget {budget}");
        });
    }
    // nested serial scope: bands forced to 1 regardless of the override
    threads::with_budget(8, || {
        threads::serial(|| {
            assert_eq!(matmul(&a, &b).data, base_nn.data, "nn nested serial");
            assert_eq!(matmul_at_b(&a, &b2).data, base_tn.data, "tn nested serial");
            assert_eq!(matmul_a_bt(&a, &bt).data, base_nt.data, "nt nested serial");
        });
    });
}

/// 0 = NaN, 1 = +Inf, 2 = -Inf, 3 = finite.
fn classify(x: f32) -> u8 {
    if x.is_nan() {
        0
    } else if x == f32::INFINITY {
        1
    } else if x == f32::NEG_INFINITY {
        2
    } else {
        3
    }
}

#[test]
fn packed_simd_kernels_match_oracle_with_nan_inf() {
    // The packed/SIMD kernels only reorder *summation* within a row; the
    // product multiset per output element is identical to the scalar
    // oracle, so NaN/±Inf classes must agree exactly (a NaN or a mixed
    // ±Inf pair poisons the sum in every order) and finite values within
    // tolerance. Injects NaN/Inf/zeros on adversarial shapes.
    prop::check(48, |rng| {
        let (m, k, n) = (adversarial_dim(rng), adversarial_dim(rng), adversarial_dim(rng));
        let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0];
        let mut a = rng.gaussian_tensor(&[m, k], 1.0);
        let mut b = rng.gaussian_tensor(&[k, n], 1.0);
        for _ in 0..3 {
            let ia = rng.below(a.data.len());
            a.data[ia] = specials[rng.below(4)];
            let ib = rng.below(b.data.len());
            b.data[ib] = specials[rng.below(4)];
        }
        let tol = 1e-3 * (k.max(m) as f64).sqrt();
        // nn
        let (fast, slow) = (matmul(&a, &b), scalar_matmul(&a, &b));
        for (i, (x, y)) in fast.data.iter().zip(&slow.data).enumerate() {
            prop::assert_true(
                classify(*x) == classify(*y),
                &format!("nn class mismatch at {i} ({m},{k},{n}): {x} vs {y}"),
            )?;
            if classify(*x) == 3 {
                prop::assert_lt((x - y).abs() as f64, tol, "nn finite")?;
            }
        }
        // tn: A^T (m,k) with B (m,n)
        let b_tn = {
            let mut t = rng.gaussian_tensor(&[m, n], 1.0);
            let i = rng.below(t.data.len());
            t.data[i] = specials[rng.below(4)];
            t
        };
        let (fast, slow) = (matmul_at_b(&a, &b_tn), scalar_matmul_at_b(&a, &b_tn));
        for (i, (x, y)) in fast.data.iter().zip(&slow.data).enumerate() {
            prop::assert_true(
                classify(*x) == classify(*y),
                &format!("tn class mismatch at {i} ({m},{k},{n}): {x} vs {y}"),
            )?;
            if classify(*x) == 3 {
                prop::assert_lt((x - y).abs() as f64, tol, "tn finite")?;
            }
        }
        // nt: A (m,k) with B^T (n,k)
        let b_nt = {
            let mut t = rng.gaussian_tensor(&[n, k], 1.0);
            let i = rng.below(t.data.len());
            t.data[i] = specials[rng.below(4)];
            t
        };
        let fast = matmul_a_bt(&a, &b_nt);
        let slow = scalar_matmul_a_bt(&a, &b_nt);
        for (i, (x, y)) in fast.data.iter().zip(&slow.data).enumerate() {
            prop::assert_true(
                classify(*x) == classify(*y),
                &format!("nt class mismatch at {i} ({m},{k},{n}): {x} vs {y}"),
            )?;
            if classify(*x) == 3 {
                prop::assert_lt((x - y).abs() as f64, tol, "nt finite")?;
            }
        }
        Ok(())
    });
}

#[test]
fn nan_propagation_regression() {
    // Zero row in A, NaN in B: the old zero-skip dropped the NaN.
    let mut a = Tensor::zeros(&[3, 2]);
    a.set2(2, 0, 1.0);
    let mut b = Tensor::new(vec![2, 2], vec![f32::NAN, 1.0, 2.0, 3.0]).unwrap();
    let c = matmul(&a, &b);
    assert!(c.at2(0, 0).is_nan() && c.at2(1, 0).is_nan() && c.at2(2, 0).is_nan());
    assert!(c.at2(0, 1).is_finite());
    b.set2(0, 0, f32::INFINITY);
    let c = scalar_matmul(&a, &b);
    assert!(c.at2(0, 0).is_nan(), "0 * Inf must be NaN, not skipped");
}

#[test]
fn factored_recompression_property() {
    // On adversarial shapes the factored sketch must agree with the direct
    // recompression of the materialized matrix.
    prop::check(32, |rng| {
        let m = rng.range(2, 50);
        let n = rng.range(2, 50);
        let l = rng.range(1, 7).min(m).min(n);
        let beta = [0.0f32, 0.5, 0.8, 0.99][rng.below(4)];
        let mut ws = Workspace::new();
        let qp = mlorc::linalg::mgs_qr(&rng.gaussian_tensor(&[m, l], 1.0));
        let bp = rng.gaussian_tensor(&[l, n], 0.7);
        let g = rng.gaussian_tensor(&[m, n], 1.0);
        let omega = rng.gaussian_tensor(&[n, l], 1.0);

        let mut a = matmul(&qp, &bp);
        a.axpy(1.0 - beta, &g, beta);
        let (qd, bd) = rsvd_qb(&a, &omega);
        let (qf, bf) = rsvd_qb_factored(&qp, &bp, beta, &g, &omega, &mut ws);
        let direct = matmul(&qd, &bd);
        let fact = matmul(&qf, &bf);
        let denom = direct.norm_fro().max(1e-6);
        prop::assert_lt(
            (fact.max_abs_diff(&direct) / denom) as f64,
            5e-4,
            &format!("factored vs direct ({m},{n},{l},beta={beta})"),
        )
    });
}
