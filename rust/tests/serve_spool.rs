//! Serve integration: a spool of jobs drains concurrently with per-job
//! results bit-identical to solo runs at the same thread budget, and an
//! interrupted job recovers + resumes to bit-identical final params.

use std::path::PathBuf;

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::linalg::threads;
use mlorc::serve::{aggregate, serve, Engine, HostTrainer, JobSpec, ServeOpts, Spool};
use mlorc::tensor::Tensor;

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mlorc_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn job_cfg(method: Method, seed: u64, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::new("host-nano", method, TaskKind::MathChain, steps);
    cfg.peak_lr = 0.03;
    cfg.log_every = 0;
    cfg.seed = seed;
    cfg
}

/// Final parameters of a finished job, read back through its final v2
/// checkpoint (the scheduler always writes one).
fn final_params(spool: &Spool, id: &str) -> Vec<Tensor> {
    let spec = spool.load_spec("done", id).unwrap();
    let mut tr = HostTrainer::new(spec.cfg.clone()).unwrap();
    tr.resume_from(&spool.checkpoint_root(id)).unwrap();
    assert_eq!(tr.step_count(), spec.cfg.steps, "job {id} final checkpoint not at last step");
    tr.params.values.clone()
}

#[test]
fn spool_drains_concurrently_and_matches_solo() {
    let root = tmp("drain");
    let spool = Spool::open(&root).unwrap();
    let jobs =
        [(Method::MlorcAdamW, 11u64), (Method::MlorcLion, 22u64), (Method::Galore, 33u64)];
    for (i, (method, seed)) in jobs.iter().enumerate() {
        let spec = JobSpec {
            id: format!("job{:03}_{}", i + 1, method.name()),
            engine: Engine::Host,
            checkpoint_every: 4,
            priority: 0,
            attempts: Vec::new(),
            not_before_unix_ms: 0,
            cfg: job_cfg(*method, *seed, 10),
        };
        spool.submit(&spec).unwrap();
    }

    let opts = ServeOpts { jobs: 2, drain: true, poll_ms: 20, ..Default::default() };
    let summary = serve(&spool, &opts).unwrap();
    assert_eq!(summary.done, 3, "all jobs must drain");
    assert_eq!(summary.failed, 0);
    assert_eq!(spool.jobs_in("done").unwrap().len(), 3);
    assert!(spool.jobs_in("queue").unwrap().is_empty());
    assert!(spool.jobs_in("running").unwrap().is_empty());

    // Per-job results must be bit-identical to solo runs at the same
    // thread slice the scheduler gave each job.
    let slice = (threads::budget() / 2).max(1);
    for (i, (method, seed)) in jobs.iter().enumerate() {
        let id = format!("job{:03}_{}", i + 1, method.name());
        let served = final_params(&spool, &id);
        let solo = threads::with_budget(slice, || {
            let mut tr = HostTrainer::new(job_cfg(*method, *seed, 10)).unwrap();
            for _ in 0..10 {
                tr.train_step().unwrap();
            }
            tr.params.values.clone()
        });
        assert_eq!(served.len(), solo.len());
        for (j, (a, b)) in served.iter().zip(&solo).enumerate() {
            assert_eq!(a.data, b.data, "job {id} param {j} != solo run");
        }
    }

    // status aggregation agrees with the lifecycle dirs
    let rows = aggregate(&spool).unwrap();
    assert_eq!(rows.len(), 3);
    assert!(rows.iter().all(|r| r.state == "done"), "{rows:?}");
    assert!(rows.iter().all(|r| r.step == 10));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn interrupted_job_recovers_and_resumes_bit_identical() {
    let root = tmp("crash");
    let spool = Spool::open(&root).unwrap();
    let cfg = job_cfg(Method::MlorcAdamW, 7, 12);
    let spec = JobSpec {
        id: "job001_crash".to_string(),
        engine: Engine::Host,
        checkpoint_every: 5,
        priority: 0,
        attempts: Vec::new(),
        not_before_unix_ms: 0,
        cfg: cfg.clone(),
    };
    spool.submit(&spec).unwrap();

    // Simulate a crashed scheduler: claim the job, run 5 steps, write
    // the cadence checkpoint, and die without finishing — the spec stays
    // stranded in running/ exactly as after a kill -9.
    let claimed = spool.claim_next().unwrap().unwrap();
    assert_eq!(claimed.id, "job001_crash");
    let mut tr = HostTrainer::new(claimed.cfg.clone()).unwrap();
    for _ in 0..5 {
        tr.train_step().unwrap();
    }
    tr.save_checkpoint(&spool.checkpoint_root(&claimed.id)).unwrap();
    drop(tr);

    // Restart: recovery sweeps running/ back into queue/, the worker
    // resumes from the checkpoint and completes the job. The manual
    // claim above wrote no lease, so legacy mode (lease timeout 0)
    // recovers it unconditionally at startup.
    let opts =
        ServeOpts { jobs: 2, drain: true, poll_ms: 20, lease_timeout_ms: 0, ..Default::default() };
    let summary = serve(&spool, &opts).unwrap();
    assert_eq!(summary.recovered, 1);
    assert_eq!(summary.done, 1);
    assert_eq!(summary.failed, 0);

    let served = final_params(&spool, "job001_crash");
    let mut solo = HostTrainer::new(cfg).unwrap();
    for _ in 0..12 {
        solo.train_step().unwrap();
    }
    for (j, (a, b)) in served.iter().zip(&solo.params.values).enumerate() {
        assert_eq!(a.data, b.data, "param {j} != uninterrupted run");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn failing_job_lands_in_failed_with_error_status() {
    let root = tmp("fail");
    let spool = Spool::open(&root).unwrap();
    // The graph engine without artifacts (or without the pjrt feature)
    // must fail cleanly — failed/ + error in status — not wedge a worker.
    let spec = JobSpec {
        id: "job001_graph".to_string(),
        engine: Engine::Graph,
        checkpoint_every: 0,
        priority: 0,
        attempts: Vec::new(),
        not_before_unix_ms: 0,
        cfg: job_cfg(Method::MlorcAdamW, 1, 4),
    };
    spool.submit(&spec).unwrap();
    // max_retries 0: a deterministic failure goes straight to failed/
    // (the retry path is pinned by tests/fault_injection.rs)
    let opts =
        ServeOpts { jobs: 1, drain: true, poll_ms: 20, max_retries: 0, ..Default::default() };
    let summary = serve(&spool, &opts).unwrap();
    // host-nano is not a manifest preset, so the graph engine can never
    // run this job — with or without artifacts it must fail cleanly
    assert_eq!(summary.failed, 1);
    assert_eq!(summary.done, 0);
    let rows = aggregate(&spool).unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].state, "failed");
    assert!(rows[0].error.is_some(), "failed job must carry its error");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn priorities_and_cancellation_shape_the_drain() {
    // An urgent late submission overtakes the backlog, a cancelled job is
    // never claimed, and the drain still reports a clean spool. Runs the
    // post-refactor registry combos end-to-end through the scheduler.
    let root = tmp("prio");
    let spool = Spool::open(&root).unwrap();
    let mk = |id: &str, method: Method, priority: i64| JobSpec {
        id: id.to_string(),
        engine: Engine::Host,
        checkpoint_every: 0,
        priority,
        attempts: Vec::new(),
        not_before_unix_ms: 0,
        cfg: job_cfg(method, 5, 6),
    };
    spool.submit(&mk("job001_doomed", Method::MlorcAdamW, 0)).unwrap();
    spool.submit(&mk("job002_backlog", Method::MlorcSgdM, 0)).unwrap();
    spool.submit(&mk("job003_urgent", Method::GaloreLion, 9)).unwrap();
    spool.cancel("job001_doomed").unwrap();

    // Single worker: claim order is fully deterministic — urgent first.
    let first = spool.claim_next().unwrap().unwrap();
    assert_eq!(first.id, "job003_urgent");
    assert_eq!(first.priority, 9);
    // put it back so the scheduler drains everything itself (the manual
    // claim wrote no lease, so legacy-mode recovery sweeps it)
    spool.recover_interrupted(0).unwrap();

    let opts = ServeOpts { jobs: 1, drain: true, poll_ms: 20, ..Default::default() };
    let summary = serve(&spool, &opts).unwrap();
    assert_eq!(summary.done, 2);
    assert_eq!(summary.failed, 0);
    assert_eq!(spool.jobs_in("cancelled").unwrap(), vec!["job001_doomed"]);

    let rows = aggregate(&spool).unwrap();
    assert_eq!(rows.len(), 3);
    let state_of = |id: &str| {
        rows.iter().find(|r| r.id == id).map(|r| r.state.clone()).unwrap()
    };
    assert_eq!(state_of("job001_doomed"), "cancelled");
    assert_eq!(state_of("job002_backlog"), "done");
    assert_eq!(state_of("job003_urgent"), "done");
    // both new registry combos produced resumable final checkpoints
    assert!(!final_params(&spool, "job002_backlog").is_empty());
    assert!(!final_params(&spool, "job003_urgent").is_empty());
    std::fs::remove_dir_all(&root).unwrap();
}
