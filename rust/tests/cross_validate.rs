//! Three-way agreement: the lowered HLO step graphs (Pallas path) must
//! match the pure-rust reference optimizers given identical inputs and
//! identical Omega draws. The python pytest suite already pins the HLO
//! builders to the jnp oracle, so passing here closes the triangle.

use mlorc::config::Method;
use mlorc::linalg::Rng;
use mlorc::optim::{
    AdamWState, GaloreState, LdAdamWState, LionState, MlorcAdamWState, MlorcLionState, OptHp,
};
use mlorc::runtime::{HostValue, Manifest, Runtime};
use mlorc::tensor::Tensor;
use mlorc::util::fsutil;

fn setup() -> Option<(Manifest, Runtime)> {
    let dir = fsutil::artifacts_dir().ok()?;
    if !dir.join("manifest.json").exists() {
        return None;
    }
    Some((Manifest::load(&dir).unwrap(), Runtime::cpu(&dir).unwrap()))
}

const SHAPE: [usize; 2] = [64, 256];
const KEY: &str = "64x256";
const TOL: f32 = 2e-3; // f32 reassociation across three matmul paths

#[test]
fn hparams_match_rust_defaults() {
    let Some((manifest, _)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let sg = preset.opt_step("mlorc_adamw", KEY).unwrap();
    let hp = OptHp::from_json(&sg.hparams);
    assert_eq!(hp, OptHp::mlorc_adamw());
    let sg = preset.opt_step("adamw", KEY).unwrap();
    assert_eq!(OptHp::from_json(&sg.hparams), OptHp::adamw());
    let sg = preset.opt_step("lion", KEY).unwrap();
    assert_eq!(OptHp::from_json(&sg.hparams), OptHp::lion());
}

#[test]
fn mlorc_adamw_hlo_matches_rust_mirror_over_5_steps() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let sg = preset.opt_step("mlorc_adamw", KEY).unwrap();
    let hp = OptHp::mlorc_adamw();
    let l = sg.l;
    let mut rng = Rng::new(11);
    let mut w_hlo = rng.gaussian_tensor(&SHAPE, 0.5);
    let mut w_rs = w_hlo.clone();
    let mut mirror = MlorcAdamWState::new(&SHAPE, l);
    let (mut mq, mut mb) = (Tensor::zeros(&[SHAPE[0], l]), Tensor::zeros(&[l, SHAPE[1]]));
    let (mut vq, mut vb) = (mq.clone(), mb.clone());
    let mut om_rng_hlo = Rng::new(77);
    let mut om_rng_rs = Rng::new(77);
    for t in 1..=5 {
        let g = rng.gaussian_tensor(&SHAPE, 1.0);
        let lr = 1e-2f32;
        let c1 = 1.0 / (1.0 - hp.beta1.powi(t));
        let c2 = 1.0 / (1.0 - hp.beta2.powi(t));
        // identical Omega draws (same order as the mirror: om_m then om_v)
        let om_m = om_rng_hlo.gaussian_tensor(&[SHAPE[1], l], 1.0);
        let om_v = om_rng_hlo.gaussian_tensor(&[SHAPE[1], l], 1.0);
        let outs = rt
            .run(
                sg,
                &[
                    w_hlo.clone().into(),
                    g.clone().into(),
                    mq.into(),
                    mb.into(),
                    vq.into(),
                    vb.into(),
                    om_m.into(),
                    om_v.into(),
                    HostValue::scalar_f32(lr),
                    HostValue::scalar_f32(c1),
                    HostValue::scalar_f32(c2),
                ],
            )
            .unwrap();
        let mut it = outs.into_iter();
        w_hlo = it.next().unwrap().into_f32().unwrap();
        mq = it.next().unwrap().into_f32().unwrap();
        mb = it.next().unwrap().into_f32().unwrap();
        vq = it.next().unwrap().into_f32().unwrap();
        vb = it.next().unwrap().into_f32().unwrap();

        mirror.step(&mut w_rs, &g, lr, &hp, &mut om_rng_rs);
        let rel = w_hlo.rel_err(&w_rs);
        assert!(rel < TOL, "step {t}: weight divergence {rel}");
        // state factors may differ by rotation; compare reconstructions
        let rec_hlo = mlorc::linalg::matmul(&mq, &mb);
        let rec_rs = mlorc::linalg::matmul(&mirror.mq, &mirror.mb);
        assert!(rec_hlo.rel_err(&rec_rs) < TOL, "step {t}: m recon divergence");
    }
}

#[test]
fn adamw_and_lion_hlo_match_mirrors() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let mut rng = Rng::new(5);

    // AdamW over 3 steps
    let sg = preset.opt_step("adamw", KEY).unwrap();
    let hp = OptHp::adamw();
    let mut w_hlo = rng.gaussian_tensor(&SHAPE, 0.5);
    let mut w_rs = w_hlo.clone();
    let mut st = AdamWState::new(&SHAPE);
    let (mut m, mut v) = (Tensor::zeros(&SHAPE), Tensor::zeros(&SHAPE));
    for t in 1..=3 {
        let g = rng.gaussian_tensor(&SHAPE, 1.0);
        let c1 = 1.0 / (1.0 - hp.beta1.powi(t));
        let c2 = 1.0 / (1.0 - hp.beta2.powi(t));
        let outs = rt
            .run(
                sg,
                &[
                    w_hlo.clone().into(),
                    g.clone().into(),
                    m.into(),
                    v.into(),
                    HostValue::scalar_f32(1e-2),
                    HostValue::scalar_f32(c1),
                    HostValue::scalar_f32(c2),
                ],
            )
            .unwrap();
        let mut it = outs.into_iter();
        w_hlo = it.next().unwrap().into_f32().unwrap();
        m = it.next().unwrap().into_f32().unwrap();
        v = it.next().unwrap().into_f32().unwrap();
        st.step(&mut w_rs, &g, 1e-2, &hp);
        assert!(w_hlo.rel_err(&w_rs) < 1e-4, "adamw step {t}");
    }

    // Lion over 3 steps
    let sg = preset.opt_step("lion", KEY).unwrap();
    let hp = OptHp::lion();
    let mut w_hlo = rng.gaussian_tensor(&SHAPE, 0.5);
    let mut w_rs = w_hlo.clone();
    let mut st = LionState::new(&SHAPE);
    let mut m = Tensor::zeros(&SHAPE);
    for t in 1..=3 {
        let g = rng.gaussian_tensor(&SHAPE, 1.0);
        let outs = rt
            .run(sg, &[w_hlo.clone().into(), g.clone().into(), m.into(), HostValue::scalar_f32(1e-3)])
            .unwrap();
        let mut it = outs.into_iter();
        w_hlo = it.next().unwrap().into_f32().unwrap();
        m = it.next().unwrap().into_f32().unwrap();
        st.step(&mut w_rs, &g, 1e-3, &hp);
        assert!(w_hlo.rel_err(&w_rs) < 1e-4, "lion step {t}");
        assert!(m.rel_err(&st.m) < 1e-4, "lion momentum step {t}");
    }
}

#[test]
fn mlorc_lion_hlo_matches_mirror() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let sg = preset.opt_step("mlorc_lion", KEY).unwrap();
    let hp = OptHp::lion();
    let l = sg.l;
    let mut rng = Rng::new(9);
    let mut w_hlo = rng.gaussian_tensor(&SHAPE, 0.5);
    let mut w_rs = w_hlo.clone();
    let mut mirror = MlorcLionState::new(&SHAPE, l);
    let (mut mq, mut mb) = (Tensor::zeros(&[SHAPE[0], l]), Tensor::zeros(&[l, SHAPE[1]]));
    let mut om_hlo = Rng::new(31);
    let mut om_rs = Rng::new(31);
    for t in 1..=4 {
        let g = rng.gaussian_tensor(&SHAPE, 1.0);
        let om = om_hlo.gaussian_tensor(&[SHAPE[1], l], 1.0);
        let outs = rt
            .run(
                sg,
                &[
                    w_hlo.clone().into(),
                    g.clone().into(),
                    mq.into(),
                    mb.into(),
                    om.into(),
                    HostValue::scalar_f32(1e-3),
                ],
            )
            .unwrap();
        let mut it = outs.into_iter();
        w_hlo = it.next().unwrap().into_f32().unwrap();
        mq = it.next().unwrap().into_f32().unwrap();
        mb = it.next().unwrap().into_f32().unwrap();
        mirror.step(&mut w_rs, &g, 1e-3, &hp, &mut om_rs);
        assert!(w_hlo.rel_err(&w_rs) < TOL, "mlorc_lion step {t}: {}", w_hlo.rel_err(&w_rs));
    }
}

#[test]
fn galore_hlo_matches_mirror_first_step() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let sg = preset.opt_step("galore", KEY).unwrap();
    let proj = preset.opt_step("galore_project", KEY).unwrap();
    let hp = OptHp::adamw();
    let l = sg.l;
    let mut rng = Rng::new(21);
    let g = rng.gaussian_tensor(&SHAPE, 1.0);
    let w0 = rng.gaussian_tensor(&SHAPE, 0.5);

    // HLO path: project then step
    let om = Rng::new(55).gaussian_tensor(&[SHAPE[1], l], 1.0);
    let p = rt
        .run(proj, &[g.clone().into(), om.into()])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let outs = rt
        .run(
            sg,
            &[
                w0.clone().into(),
                g.clone().into(),
                p.into(),
                Tensor::zeros(&[l, SHAPE[1]]).into(),
                Tensor::zeros(&[l, SHAPE[1]]).into(),
                HostValue::scalar_f32(1e-2),
                HostValue::scalar_f32(10.0),
                HostValue::scalar_f32(1000.0),
            ],
        )
        .unwrap();
    let w_hlo = outs[0].as_f32().unwrap().clone();

    // rust mirror with the same Omega stream
    let mut st = GaloreState::new(&SHAPE, l, 100);
    let mut w_rs = w0.clone();
    let mut om_rng = Rng::new(55);
    st.step(&mut w_rs, &g, 1e-2, &hp, &mut om_rng);
    assert!(w_hlo.rel_err(&w_rs) < TOL, "galore: {}", w_hlo.rel_err(&w_rs));
}

#[test]
fn ldadamw_hlo_matches_mirror_first_step() {
    let Some((manifest, rt)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    let sg = preset.opt_step("ldadamw", KEY).unwrap();
    let hp = OptHp::adamw();
    let l = sg.l;
    let mut rng = Rng::new(33);
    let g = rng.gaussian_tensor(&SHAPE, 1.0);
    let w0 = rng.gaussian_tensor(&SHAPE, 0.5);
    let left = SHAPE[0] <= SHAPE[1];
    assert!(left);
    let om = Rng::new(66).gaussian_tensor(&[SHAPE[1], l], 1.0);
    let outs = rt
        .run(
            sg,
            &[
                w0.clone().into(),
                g.clone().into(),
                Tensor::zeros(&[SHAPE[0], l]).into(), // p_old
                Tensor::zeros(&[l, SHAPE[1]]).into(),
                Tensor::zeros(&[l, SHAPE[1]]).into(),
                Tensor::zeros(&SHAPE).into(), // e
                om.into(),
                HostValue::scalar_f32(1e-2),
                HostValue::scalar_f32(10.0),
                HostValue::scalar_f32(1000.0),
            ],
        )
        .unwrap();
    let w_hlo = outs[0].as_f32().unwrap().clone();
    let e_hlo = outs[4].as_f32().unwrap().clone();

    let mut st = LdAdamWState::new(&SHAPE, l);
    // align init: mirror uses identity-seeded p_old, but with M=V=0 the
    // rotation contributes nothing on step 1, matching the zero p_old.
    let mut w_rs = w0.clone();
    let mut om_rng = Rng::new(66);
    st.step(&mut w_rs, &g, 1e-2, &hp, &mut om_rng);
    assert!(w_hlo.rel_err(&w_rs) < TOL, "ldadamw w: {}", w_hlo.rel_err(&w_rs));
    assert!(e_hlo.rel_err(&st.e) < TOL, "ldadamw e: {}", e_hlo.rel_err(&st.e));
}

#[test]
fn method_enum_covers_all_manifest_opt_methods() {
    let Some((manifest, _)) = setup() else { return };
    let preset = manifest.preset("nano").unwrap();
    for name in preset.opt_steps.keys() {
        if name == "galore_project" {
            continue;
        }
        // every lowered method must be reachable from some Method routing
        let reachable = Method::all().iter().any(|m| {
            m.matrix_step() == name || m.plain_step() == name
        });
        assert!(reachable, "opt method '{name}' unreachable from Method enum");
    }
}
