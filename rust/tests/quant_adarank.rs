//! Acceptance tests for the PR-5 compressors.
//!
//! * `QuantQb`: dequantize∘quantize error is bounded per element by half
//!   a code step — `absmax(block) / 254` (we allow /253 for f32 rounding
//!   slack) — as a property over random tensors, and the quantized
//!   optimizer tracks its f32 twin on the synthetic least-squares task.
//! * `AdaRank`: the factor rank never increases, shrinks to `--rank-min`
//!   when the momentum is genuinely low-rank, and final weights stay
//!   within tolerance of fixed-rank `RsvdQb` on the synthetic
//!   least-squares task.

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::OptState;
use mlorc::linalg::{Rng, Workspace};
use mlorc::optim::{QTensor, Q8_BLOCK};
use mlorc::serve::HostTrainer;
use mlorc::tensor::Tensor;
use mlorc::testing::prop;

// ----------------------------------------------------------------- quant

#[test]
fn quantize_error_bounded_by_block_absmax() {
    prop::check(32, |rng| {
        let m = rng.range(1, 40);
        let n = rng.range(1, 40);
        let scale = (0.1 + 10.0 * rng.uniform()) as f32;
        let t = rng.gaussian_tensor(&[m, n], scale);
        let q = QTensor::quantize(&t, Q8_BLOCK);
        let back = q.dequantize();
        for (bi, chunk) in t.data.chunks(Q8_BLOCK).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            for (j, (&x, &y)) in
                chunk.iter().zip(&back.data[bi * Q8_BLOCK..bi * Q8_BLOCK + chunk.len()]).enumerate()
            {
                let err = (x - y).abs() as f64;
                let bound = absmax as f64 / 253.0;
                if err > bound {
                    return Err(format!("block {bi} elem {j}: err {err} > bound {bound}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn q8_checkpoint_fields_roundtrip_codes_and_scales() {
    // The state's checkpoint surface must carry both planes: f32 scales
    // via tensor_fields, u8 codes via u8_fields, recombined by the
    // registry decoder.
    let mut st = OptState::for_variant("mlorc_q8", &[12, 20], 4).unwrap();
    // run one real step so codes are nonzero
    let mut rng = Rng::new(3);
    let mut w = rng.gaussian_tensor(&[12, 20], 0.5);
    let g = rng.gaussian_tensor(&[12, 20], 1.0);
    let mut ws = Workspace::new();
    st.host_step(&mut w, &g, 1e-2, 1, &mut rng, &mut ws).unwrap();
    assert!(
        st.u8_fields().iter().any(|(_, t)| t.data.iter().any(|&c| c != 0)),
        "a real step must produce nonzero codes"
    );

    let fields: std::collections::BTreeMap<&'static str, Tensor> =
        st.tensor_fields().into_iter().map(|(k, t)| (k, t.clone())).collect();
    let u8s: std::collections::BTreeMap<&'static str, mlorc::tensor::TensorU8> =
        st.u8_fields().into_iter().map(|(k, t)| (k, t.clone())).collect();
    assert_eq!(
        fields.keys().copied().collect::<Vec<_>>(),
        vec!["mb_sc", "mq_sc", "vb_sc", "vq_sc"]
    );
    assert_eq!(
        u8s.keys().copied().collect::<Vec<_>>(),
        vec!["mb_q8", "mq_q8", "vb_q8", "vq_q8"]
    );
    let back = OptState::from_ckpt_full(
        &st.ckpt_meta(),
        |k| fields.get(k).cloned().ok_or_else(|| anyhow::anyhow!("missing {k}")),
        |k| u8s.get(k).cloned().ok_or_else(|| anyhow::anyhow!("missing u8 {k}")),
        |k| anyhow::bail!("unexpected bf16 plane {k}"),
    )
    .unwrap();
    assert_eq!(back.variant_name(), "mlorc_q8");
    assert_eq!(back.state_bytes(), st.state_bytes());
    for ((na, ta), (nb, tb)) in back.u8_fields().iter().zip(st.u8_fields().iter()) {
        assert_eq!(na, nb);
        assert_eq!(ta.data, tb.data, "codes must roundtrip byte-exact");
    }
}

#[test]
fn q8_state_is_fraction_of_f32_factored() {
    let q8 = OptState::for_variant("mlorc_q8", &[512, 128], 4).unwrap();
    let f32v = OptState::for_variant("mlorc_adamw", &[512, 128], 4).unwrap();
    let dense = OptState::for_variant("adamw", &[512, 128], 4).unwrap();
    assert!(q8.state_bytes() < f32v.state_bytes() / 3);
    assert!(
        10 * q8.state_bytes() <= 3 * dense.state_bytes(),
        "q8 {}B vs dense {}B",
        q8.state_bytes(),
        dense.state_bytes()
    );
}

#[test]
fn q8_tracks_f32_mlorc_on_least_squares() {
    // The quantized optimizer must still train: loss decreases, and the
    // final parameters stay close to the f32 factored run (quantization
    // noise is bounded per step, not accumulated catastrophically).
    let mk = |method: Method| {
        let mut cfg = RunConfig::new("host-nano", method, TaskKind::MathChain, 30);
        cfg.peak_lr = 0.05;
        cfg.log_every = 0;
        cfg.seed = 9;
        cfg
    };
    let mut q8 = HostTrainer::new(mk(Method::MlorcQ8)).unwrap();
    let mut f32t = HostTrainer::new(mk(Method::MlorcAdamW)).unwrap();
    let first = q8.train_step().unwrap();
    f32t.train_step().unwrap();
    let mut last = first;
    for _ in 0..29 {
        last = q8.train_step().unwrap();
        f32t.train_step().unwrap();
    }
    assert!(last < first * 0.9, "q8 loss did not decrease: {first} -> {last}");
    for (a, b) in q8.params.values.iter().zip(&f32t.params.values) {
        let rel = a.rel_err(b);
        assert!(rel < 0.1, "q8 diverged from f32 mlorc: rel {rel}");
    }
}

// --------------------------------------------------------------- adarank

/// Per-moment factor ranks of a state (from the stored tensor shapes).
fn ranks(st: &OptState) -> Vec<usize> {
    st.tensor_fields()
        .iter()
        .filter(|(name, _)| name.ends_with('q') && *name != "q") // mq / vq
        .map(|(_, t)| t.shape[1])
        .collect()
}

#[test]
fn adarank_rank_never_increases_and_shrinks_on_lowrank_momentum() {
    // A constant rank-1 gradient g = u v^T keeps both momenta exactly
    // rank 1 (the second moment's elementwise square u²(v²)^T is rank 1
    // too), so the tail energy of B collapses and the rank must shrink
    // to the floor — and never go back up. The factor recompression
    // depends only on g and the factors, so no training loop is needed.
    let (m, n, l, rank_min) = (24usize, 16usize, 6usize, 2usize);
    let v = mlorc::optim::registry::variant("mlorc_adarank").unwrap();
    let mut st = OptState::Opt(v.build_opts(&[m, n], l, rank_min).unwrap());

    let mut rng = Rng::new(5);
    let u = rng.gaussian_tensor(&[m, 1], 1.0);
    let vt = rng.gaussian_tensor(&[1, n], 1.0);
    let g = mlorc::linalg::matmul(&u, &vt);
    let mut w = Tensor::zeros(&[m, n]);
    let mut ws = Workspace::new();
    let mut om_rng = Rng::new(7);
    let mut prev = ranks(&st);
    assert_eq!(prev, vec![l, l]);
    for t in 1..=40 {
        st.host_step(&mut w, &g, 0.05, t, &mut om_rng, &mut ws).unwrap();
        let cur = ranks(&st);
        for (c, p) in cur.iter().zip(&prev) {
            assert!(c <= p, "rank increased: {prev:?} -> {cur:?} at step {t}");
        }
        for &c in &cur {
            assert!(c >= rank_min, "rank fell below the floor: {cur:?}");
        }
        prev = cur;
    }
    assert!(
        prev.iter().all(|&r| r == rank_min),
        "rank-1 momentum must shrink to rank_min {rank_min}: {prev:?}"
    );
    assert!(st.shrink_events() > 0, "shrinks must be counted");

    // The shrunken (variable-rank) state must decode back from its own
    // checkpoint surface: shapes carry the live rank, flags carry the
    // floor and the shrink counter.
    let fields: std::collections::BTreeMap<&'static str, Tensor> =
        st.tensor_fields().into_iter().map(|(k, t)| (k, t.clone())).collect();
    let back = OptState::from_ckpt(&st.ckpt_meta(), |k| {
        fields.get(k).cloned().ok_or_else(|| anyhow::anyhow!("missing {k}"))
    })
    .unwrap();
    assert_eq!(ranks(&back), ranks(&st));
    assert_eq!(back.shrink_events(), st.shrink_events());
    for ((na, ta), (nb, tb)) in back.tensor_fields().iter().zip(st.tensor_fields().iter()) {
        assert_eq!(na, nb);
        assert_eq!(ta.data, tb.data, "field {na} must roundtrip byte-exact");
    }
}

#[test]
fn adarank_matches_fixed_rank_on_least_squares() {
    // On the full-rank synthetic least-squares task the directions all
    // carry non-negligible energy. While no shrink fires, AdaRank's step
    // is the *same* kernel sequence and Omega schedule as fixed-rank
    // RsvdQb, so the runs must be bit-identical; if a borderline shrink
    // does fire (it drops < 1% of the momentum energy), final weights
    // must still stay within tolerance.
    let mk = |method: Method| {
        let mut cfg = RunConfig::new("host-nano", method, TaskKind::MathChain, 25);
        cfg.peak_lr = 0.05;
        cfg.log_every = 0;
        cfg.seed = 13;
        cfg
    };
    let mut ada = HostTrainer::new(mk(Method::MlorcAdaRank)).unwrap();
    let mut fixed = HostTrainer::new(mk(Method::MlorcAdamW)).unwrap();
    for _ in 0..25 {
        ada.train_step().unwrap();
        fixed.train_step().unwrap();
    }
    for (a, b) in ada.params.values.iter().zip(&fixed.params.values) {
        if ada.shrink_events() == 0 {
            assert_eq!(a.data, b.data, "no shrink: adarank must equal fixed-rank to the bit");
        } else {
            let rel = a.rel_err(b);
            assert!(rel < 0.05, "adarank drifted from fixed-rank rsvd_qb: rel {rel}");
        }
    }
}

#[test]
fn adarank_shrunken_state_resumes_bit_identical() {
    // A shrink mid-run must survive the checkpoint: variable-rank shapes
    // + rank_min + shrink counter roundtrip, and the continuation is
    // bit-identical to the uninterrupted run.
    let dir = std::env::temp_dir()
        .join(format!("mlorc_adarank_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = RunConfig::new("host-nano", Method::MlorcAdaRank, TaskKind::MathChain, 12);
    cfg.peak_lr = 0.03;
    cfg.log_every = 0;
    cfg.seed = 4;
    cfg.rank_min = 2;
    let mut tr = HostTrainer::new(cfg.clone()).unwrap();
    for _ in 0..6 {
        tr.train_step().unwrap();
    }
    tr.save_checkpoint(&dir).unwrap();
    let mut resumed = HostTrainer::new(cfg).unwrap();
    assert_eq!(resumed.resume_from(&dir).unwrap(), 6);
    for _ in 0..6 {
        tr.train_step().unwrap();
        resumed.train_step().unwrap();
    }
    for (j, (a, b)) in tr.params.values.iter().zip(&resumed.params.values).enumerate() {
        assert_eq!(a.data, b.data, "param {j} diverged after adarank resume");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
