//! The optimizer-matrix refactor's acceptance gates.
//!
//! 1. **Bit-exactness vs the pre-refactor dispatch**: `legacy` below is a
//!    verbatim transcription of the old `OptState::host_step` match
//!    ladder (same `*_core` kernels, same hyper-parameters, same Omega
//!    draw order). Every pre-existing method id, resolved through the new
//!    registry, must reproduce it bit-for-bit over a ≥10-step run —
//!    weights *and* every state tensor.
//! 2. **Combo matrix**: every registered (rule × compressor) method runs
//!    5 host steps, checkpoints, and roundtrips the checkpoint
//!    byte-exactly — newly registered methods get this coverage
//!    automatically, and the resumed trainer must continue bit-identically.

use std::collections::BTreeMap;
use std::path::PathBuf;

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::{load_checkpoint_v2, OptState};
use mlorc::linalg::{Rng, Workspace};
use mlorc::optim::{
    adamw_host_step, galore_core, galore_refresh_projector, ldadamw_core, lion_host_step,
    mlorc_adamw_core, mlorc_lion_core, mlorc_m_core, mlorc_v_core, OptHp,
};
use mlorc::runtime::ParamSpec;
use mlorc::serve::HostTrainer;
use mlorc::tensor::Tensor;

// ------------------------------------------------------- legacy oracle

/// The pre-refactor per-parameter state, as the enum used to hold it.
enum Legacy {
    AdamW { m: Tensor, v: Tensor },
    Lion { m: Tensor },
    MlorcAdamW { mq: Tensor, mb: Tensor, vq: Tensor, vb: Tensor },
    MlorcLion { mq: Tensor, mb: Tensor },
    MlorcM { mq: Tensor, mb: Tensor, v: Tensor },
    MlorcV { m: Tensor, vq: Tensor, vb: Tensor },
    Galore { p: Tensor, m_lo: Tensor, v_lo: Tensor, left: bool, refreshed: bool },
    LdAdamW { p: Tensor, m_lo: Tensor, v_lo: Tensor, e: Tensor, left: bool },
}

impl Legacy {
    /// Zero state exactly as the old `OptState::for_param_with_l` built it.
    fn new(method: &str, m: usize, n: usize, l: usize) -> Legacy {
        let left = m <= n;
        let (pshape, rshape) = if left { ([m, l], [l, n]) } else { ([n, l], [m, l]) };
        match method {
            "full_adamw" => {
                Legacy::AdamW { m: Tensor::zeros(&[m, n]), v: Tensor::zeros(&[m, n]) }
            }
            "full_lion" => Legacy::Lion { m: Tensor::zeros(&[m, n]) },
            "mlorc_adamw" => Legacy::MlorcAdamW {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
                vq: Tensor::zeros(&[m, l]),
                vb: Tensor::zeros(&[l, n]),
            },
            "mlorc_lion" => Legacy::MlorcLion {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
            },
            "mlorc_m" => Legacy::MlorcM {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
                v: Tensor::zeros(&[m, n]),
            },
            "mlorc_v" => Legacy::MlorcV {
                m: Tensor::zeros(&[m, n]),
                vq: Tensor::zeros(&[m, l]),
                vb: Tensor::zeros(&[l, n]),
            },
            "galore" => Legacy::Galore {
                p: Tensor::zeros(&pshape),
                m_lo: Tensor::zeros(&rshape),
                v_lo: Tensor::zeros(&rshape),
                left,
                refreshed: false,
            },
            "ldadamw" => Legacy::LdAdamW {
                p: Tensor::zeros(&pshape),
                m_lo: Tensor::zeros(&rshape),
                v_lo: Tensor::zeros(&rshape),
                e: Tensor::zeros(&[m, n]),
                left,
            },
            other => panic!("no legacy oracle for '{other}'"),
        }
    }

    /// Verbatim transcription of the old `OptState::host_hp`.
    fn hp(&self) -> OptHp {
        match self {
            Legacy::Lion { .. } | Legacy::MlorcLion { .. } => OptHp::lion(),
            Legacy::MlorcAdamW { .. } | Legacy::MlorcM { .. } | Legacy::MlorcV { .. } => {
                OptHp::mlorc_adamw()
            }
            _ => OptHp::adamw(),
        }
    }

    /// Verbatim transcription of the old `OptState::host_step` dispatch.
    fn host_step(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        t: usize,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) {
        let hp = self.hp();
        match self {
            Legacy::AdamW { m, v } => adamw_host_step(w, g, m, v, lr, t, &hp),
            Legacy::Lion { m } => lion_host_step(w, g, m, lr, &hp),
            Legacy::MlorcAdamW { mq, mb, vq, vb } => {
                let (_, n) = w.dims2().unwrap();
                let l = mq.shape[1];
                let om_m = rng.gaussian_tensor(&[n, l], 1.0);
                let om_v = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_adamw_core(w, g, mq, mb, vq, vb, t, lr, &hp, &om_m, &om_v, ws);
            }
            Legacy::MlorcLion { mq, mb } => {
                let (_, n) = w.dims2().unwrap();
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_lion_core(w, g, mq, mb, lr, &hp, &om, ws);
            }
            Legacy::MlorcM { mq, mb, v } => {
                let (_, n) = w.dims2().unwrap();
                let l = mq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_m_core(w, g, mq, mb, v, t, lr, &hp, &om, ws);
            }
            Legacy::MlorcV { m, vq, vb } => {
                let (_, n) = w.dims2().unwrap();
                let l = vq.shape[1];
                let om = rng.gaussian_tensor(&[n, l], 1.0);
                mlorc_v_core(w, g, m, vq, vb, t, lr, &hp, &om, ws);
            }
            Legacy::Galore { p, m_lo, v_lo, left, refreshed } => {
                let l = p.shape[1];
                if !*refreshed {
                    galore_refresh_projector(p, g, *left, l, rng);
                    *refreshed = true;
                }
                galore_core(w, g, p, m_lo, v_lo, *left, t, lr, &hp);
            }
            Legacy::LdAdamW { p, m_lo, v_lo, e, left } => {
                let l = p.shape[1];
                ldadamw_core(w, g, p, m_lo, v_lo, e, *left, l, t, lr, &hp, rng);
            }
        }
    }

    fn clear_galore_refresh(&mut self) {
        if let Legacy::Galore { refreshed, .. } = self {
            *refreshed = false;
        }
    }

    /// Field name -> tensor, matching the checkpoint-v2 names.
    fn fields(&self) -> BTreeMap<&'static str, &Tensor> {
        let mut out = BTreeMap::new();
        match self {
            Legacy::AdamW { m, v } => {
                out.insert("m", m);
                out.insert("v", v);
            }
            Legacy::Lion { m } => {
                out.insert("m", m);
            }
            Legacy::MlorcAdamW { mq, mb, vq, vb } => {
                out.insert("mq", mq);
                out.insert("mb", mb);
                out.insert("vq", vq);
                out.insert("vb", vb);
            }
            Legacy::MlorcLion { mq, mb } => {
                out.insert("mq", mq);
                out.insert("mb", mb);
            }
            Legacy::MlorcM { mq, mb, v } => {
                out.insert("mq", mq);
                out.insert("mb", mb);
                out.insert("v", v);
            }
            Legacy::MlorcV { m, vq, vb } => {
                out.insert("m", m);
                out.insert("vq", vq);
                out.insert("vb", vb);
            }
            Legacy::Galore { p, m_lo, v_lo, .. } => {
                out.insert("p", p);
                out.insert("m_lo", m_lo);
                out.insert("v_lo", v_lo);
            }
            Legacy::LdAdamW { p, m_lo, v_lo, e, .. } => {
                out.insert("p", p);
                out.insert("m_lo", m_lo);
                out.insert("v_lo", v_lo);
                out.insert("e", e);
            }
        }
        out
    }
}

fn mat_spec(m: usize, n: usize) -> ParamSpec {
    ParamSpec { name: "w".into(), shape: vec![m, n], kind: "matrix".into(), compressed: true }
}

/// Registration assertions: the PR-5 compressors are in `Method::all()`,
/// so the combo-matrix + kill/resume coverage below picks them up with no
/// further edits to this file.
#[test]
fn adaptive_and_quantized_compressors_are_registered() {
    for id in ["mlorc_adarank", "mlorc_adarank_lion", "mlorc_q8", "mlorc_q8_lion"] {
        let m = Method::parse(id).unwrap_or_else(|e| panic!("{id} not registered: {e:#}"));
        assert!(Method::all().contains(&m), "{id} missing from Method::all()");
    }
}

/// Registration assertions for the second optimizer wave: Prodigy, bf16
/// stochastic-rounding weights and the exemplar modifier spellings are
/// registry rows, so they inherit the combo-matrix + kill/resume coverage
/// below (and the batched-vs-sequential replay in `host_parallel.rs`)
/// with no bespoke plumbing.
#[test]
fn second_optimizer_wave_is_registered() {
    for id in [
        "mlorc_prodigy",
        "mlorc_adamw_bf16",
        "mlorc_adamw_atan2",
        "mlorc_adamw_grams",
        "mlorc_adamw_ortho",
    ] {
        let m = Method::parse(id).unwrap_or_else(|e| panic!("{id} not registered: {e:#}"));
        assert!(Method::all().contains(&m), "{id} missing from Method::all()");
        assert!(!m.desc().graphed, "{id} is host-only until its step graphs are lowered");
    }
    // pinned method count: 17 pre-wave + 5 wave-2 rows
    assert_eq!(Method::all().len(), 22, "registered method count");
}

/// Every pre-existing method id, stepped through the new registry path
/// and the legacy oracle with identical gradients and Omega streams, must
/// agree to the bit — weights and every state tensor, every step.
#[test]
fn registry_path_is_bit_identical_to_prerefactor_dispatch() {
    const STEPS: usize = 12;
    const GALORE_FREQ: usize = 4;
    let methods = [
        "full_adamw",
        "full_lion",
        "mlorc_adamw",
        "mlorc_lion",
        "mlorc_m",
        "mlorc_v",
        "galore",
        "ldadamw",
    ];
    for method in methods {
        for (m, n) in [(20usize, 12usize), (12usize, 20usize)] {
            let l = 4;
            let seed = 1000 + m as u64;
            let mut data_rng = Rng::new(seed);
            let mut w_new = data_rng.gaussian_tensor(&[m, n], 0.5);
            let mut w_old = w_new.clone();

            let parsed = Method::parse(method).unwrap();
            let mut st_new =
                OptState::for_param_with_l(parsed, &mat_spec(m, n), l).unwrap();
            let mut st_old = Legacy::new(method, m, n, l);

            let mut rng_new = Rng::new(77 ^ seed);
            let mut rng_old = Rng::new(77 ^ seed);
            let mut ws_new = Workspace::new();
            let mut ws_old = Workspace::new();

            for step in 0..STEPS {
                let g = data_rng.gaussian_tensor(&[m, n], 1.0);
                // projector cadence, mirroring the trainer on both sides
                if step % GALORE_FREQ == 0 {
                    st_new.invalidate_projector();
                    st_old.clear_galore_refresh();
                }
                st_new
                    .host_step(&mut w_new, &g, 1e-2, step + 1, &mut rng_new, &mut ws_new)
                    .unwrap();
                st_old.host_step(&mut w_old, &g, 1e-2, step + 1, &mut rng_old, &mut ws_old);
                assert_eq!(
                    w_new.data, w_old.data,
                    "{method} ({m}x{n}) step {step}: weights diverged from pre-refactor path"
                );
                // the two Omega streams must stay in lock-step too
                assert_eq!(
                    rng_new.snapshot(),
                    rng_old.snapshot(),
                    "{method} ({m}x{n}) step {step}: omega stream schedule changed"
                );
            }

            let old_fields = st_old.fields();
            let new_fields = st_new.tensor_fields();
            assert_eq!(new_fields.len(), old_fields.len(), "{method}: field count");
            for (name, t) in new_fields {
                let old = old_fields.get(name).unwrap_or_else(|| {
                    panic!("{method}: field '{name}' missing from legacy state")
                });
                assert_eq!(t.data, old.data, "{method} ({m}x{n}): state field '{name}'");
            }
        }
    }
}

// ---------------------------------------------------------- combo matrix

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mlorc_matrix_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// Every registered (rule × compressor) method: 5 host steps, a v2
/// checkpoint, a byte-exact roundtrip of every state field, and a
/// bit-identical continuation — automatically covering methods registered
/// in the future.
#[test]
fn combo_matrix_checkpoint_roundtrip_bit_exact() {
    for &method in Method::all() {
        if method.is_lora() {
            continue; // host engine has no adapter graphs
        }
        let mut cfg = RunConfig::new("host-nano", method, TaskKind::MathChain, 8);
        cfg.peak_lr = 0.02;
        cfg.log_every = 0;
        cfg.seed = 21;
        cfg.galore_update_freq = 3;
        let mut tr = HostTrainer::new(cfg.clone()).unwrap();
        for _ in 0..5 {
            tr.train_step().unwrap();
        }
        let dir = tmp(method.name());
        tr.save_checkpoint(&dir).unwrap();

        // Roundtrip: every state field byte-exact through the v2 format.
        let snap = mlorc::coordinator::resolve_checkpoint_dir(&dir).unwrap();
        let mut scratch = HostTrainer::new(cfg.clone()).unwrap();
        let ck = load_checkpoint_v2(&snap, &mut scratch.params, None).unwrap();
        assert_eq!(ck.step, 5, "{method:?}");
        for (spec, live) in tr.params.specs.iter().zip(tr.opt_states()) {
            let stored: &OptState = ck
                .opt
                .get(&spec.name)
                .unwrap_or_else(|| panic!("{method:?}: no stored state for {}", spec.name));
            assert_eq!(stored.variant_name(), live.variant_name(), "{method:?}");
            assert_eq!(
                stored.ckpt_meta().to_string_compact(),
                live.ckpt_meta().to_string_compact(),
                "{method:?} {} flags",
                spec.name
            );
            let (a, b) = (live.tensor_fields(), stored.tensor_fields());
            assert_eq!(a.len(), b.len(), "{method:?} {} field count", spec.name);
            for ((na, ta), (nb, tb)) in a.iter().zip(&b) {
                assert_eq!(na, nb, "{method:?} {} field order", spec.name);
                assert_eq!(ta.shape, tb.shape, "{method:?} {}/{na} shape", spec.name);
                assert_eq!(ta.data, tb.data, "{method:?} {}/{na} bytes", spec.name);
            }
            // bf16 weight planes (dtype-3 entries) roundtrip byte-exact too
            let (a16, b16) = (live.bf16_fields(), stored.bf16_fields());
            assert_eq!(a16.len(), b16.len(), "{method:?} {} bf16 plane count", spec.name);
            for ((na, ta), (nb, tb)) in a16.iter().zip(&b16) {
                assert_eq!(na, nb, "{method:?} {} bf16 field order", spec.name);
                assert_eq!(ta.data, tb.data, "{method:?} {}/{na} bf16 bytes", spec.name);
            }
        }

        // Continuation: resumed trainer == uninterrupted trainer, to the bit.
        let mut resumed = HostTrainer::new(cfg.clone()).unwrap();
        assert_eq!(resumed.resume_from(&dir).unwrap(), 5, "{method:?}");
        for _ in 0..3 {
            tr.train_step().unwrap();
            resumed.train_step().unwrap();
        }
        for (j, (a, b)) in tr.params.values.iter().zip(&resumed.params.values).enumerate() {
            assert_eq!(a.data, b.data, "{method:?} param {j} diverged after resume");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
