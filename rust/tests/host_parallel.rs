//! Parallel host optimizer stepping must be bit-identical to sequential
//! stepping: every parameter owns its state and Omega RNG stream, and the
//! linalg kernels are banding-deterministic, so the thread schedule cannot
//! leak into the numbers.

use mlorc::coordinator::{host_step_all, HostStepJob, OptState};
use mlorc::linalg::{Rng, Workspace};
use mlorc::tensor::Tensor;

struct Fleet {
    weights: Vec<Tensor>,
    states: Vec<OptState>,
    rngs: Vec<Rng>,
}

/// A mixed bag of parameters: MLorc-AdamW matrices of several shapes,
/// MLorc-Lion, and plain AdamW/Lion tensors.
fn fleet(seed: u64) -> (Fleet, Vec<Tensor>) {
    let mut rng = Rng::new(seed);
    let l = 4;
    let shapes: Vec<Vec<usize>> = vec![
        vec![48, 20],
        vec![20, 48],
        vec![33, 7],
        vec![16, 16],
        vec![9, 31],
        vec![64, 12],
    ];
    let mut weights = Vec::new();
    let mut states = Vec::new();
    let mut rngs = Vec::new();
    let mut grads = Vec::new();
    for (i, shape) in shapes.iter().enumerate() {
        let (m, n) = (shape[0], shape[1]);
        weights.push(rng.gaussian_tensor(shape, 0.5));
        grads.push(rng.gaussian_tensor(shape, 1.0));
        states.push(match i % 4 {
            0 | 1 => OptState::MlorcAdamW {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
                vq: Tensor::zeros(&[m, l]),
                vb: Tensor::zeros(&[l, n]),
            },
            2 => OptState::MlorcLion {
                mq: Tensor::zeros(&[m, l]),
                mb: Tensor::zeros(&[l, n]),
            },
            _ => OptState::AdamW { m: Tensor::zeros(shape), v: Tensor::zeros(shape) },
        });
        // each parameter owns an independent Omega stream
        rngs.push(rng.split(100 + i as u64));
    }
    (Fleet { weights, states, rngs }, grads)
}

fn run_rounds(fleet: &mut Fleet, grads: &[Tensor], workspaces: &mut [Workspace], rounds: usize) {
    for t in 1..=rounds {
        let mut jobs: Vec<HostStepJob> = fleet
            .weights
            .iter_mut()
            .zip(fleet.states.iter_mut())
            .zip(fleet.rngs.iter_mut())
            .zip(grads.iter())
            .map(|(((w, state), rng), g)| HostStepJob {
                w,
                grad: g.clone(),
                state,
                rng,
                lr: 1e-2,
                t,
            })
            .collect();
        host_step_all(&mut jobs, workspaces).unwrap();
    }
}

#[test]
fn parallel_equals_sequential_bit_for_bit() {
    let (mut seq, grads) = fleet(7);
    let (mut par, grads2) = fleet(7);
    assert_eq!(grads.len(), grads2.len());

    let mut one_ws = vec![Workspace::new()];
    let mut many_ws: Vec<Workspace> = (0..4).map(|_| Workspace::new()).collect();
    run_rounds(&mut seq, &grads, &mut one_ws, 5);
    run_rounds(&mut par, &grads, &mut many_ws, 5);

    for (i, (a, b)) in seq.weights.iter().zip(&par.weights).enumerate() {
        assert_eq!(a.data, b.data, "weight {i} diverged between schedules");
    }
    for (i, (a, b)) in seq.states.iter().zip(&par.states).enumerate() {
        let (fa, fb) = (a.first_moment(), b.first_moment());
        match (fa, fb) {
            (Some(x), Some(y)) => assert_eq!(x.data, y.data, "state {i} first moment diverged"),
            (None, None) => {}
            _ => panic!("state {i} variant mismatch"),
        }
    }
}

#[test]
fn rerun_is_deterministic() {
    // Same seed, same schedule -> identical trajectories (RNG streams are
    // per-parameter, so this also pins the stream-splitting scheme).
    let (mut a, grads) = fleet(11);
    let (mut b, _) = fleet(11);
    let mut ws_a: Vec<Workspace> = (0..3).map(|_| Workspace::new()).collect();
    let mut ws_b: Vec<Workspace> = (0..3).map(|_| Workspace::new()).collect();
    run_rounds(&mut a, &grads, &mut ws_a, 3);
    run_rounds(&mut b, &grads, &mut ws_b, 3);
    for (x, y) in a.weights.iter().zip(&b.weights) {
        assert_eq!(x.data, y.data);
    }
}

#[test]
fn frozen_params_do_not_move() {
    let mut w = Tensor::full(&[4, 4], 1.0);
    let before = w.clone();
    let mut st = OptState::Frozen;
    let mut rng = Rng::new(0);
    let mut ws = vec![Workspace::new()];
    let mut jobs = vec![HostStepJob {
        w: &mut w,
        grad: Tensor::full(&[4, 4], 5.0),
        state: &mut st,
        rng: &mut rng,
        lr: 1.0,
        t: 1,
    }];
    host_step_all(&mut jobs, &mut ws).unwrap();
    assert_eq!(w.data, before.data);
}
