//! Parallel host optimizer stepping must be bit-identical to sequential
//! stepping: every parameter owns its state and Omega RNG stream, and the
//! linalg kernels are banding-deterministic, so neither the thread
//! schedule nor the shape-class plan (stacked kernels for same-shape
//! parameter groups) can leak into the numbers.

use mlorc::config::Method;
use mlorc::coordinator::{host_step_all, HostStepJob, OptState};
use mlorc::linalg::{threads, Rng, Workspace};
use mlorc::optim::{GaloreState, LdAdamWState, MlorcAdamWState, MlorcLionState, OptHp};
use mlorc::runtime::ParamSpec;
use mlorc::tensor::Tensor;

struct Fleet {
    weights: Vec<Tensor>,
    states: Vec<OptState>,
    rngs: Vec<Rng>,
}

/// A zero-initialized state for a registered variant (the registry owns
/// construction since the optimizer-matrix refactor).
fn state(variant: &str, m: usize, n: usize, l: usize) -> OptState {
    OptState::for_variant(variant, &[m, n], l).unwrap()
}

/// A mixed bag of parameters: MLorc-AdamW matrices of several shapes,
/// MLorc-Lion, GaLore, LDAdamW and plain AdamW tensors. The first two
/// shapes repeat at the end (same variant), so the shape-class planner
/// sees classes of size 2 next to singletons.
fn fleet(seed: u64) -> (Fleet, Vec<Tensor>) {
    let mut rng = Rng::new(seed);
    let l = 4;
    let shapes: Vec<Vec<usize>> = vec![
        vec![48, 20],
        vec![20, 48],
        vec![33, 7],
        vec![16, 16],
        vec![9, 31],
        vec![64, 12],
        vec![48, 20],
        vec![20, 48],
    ];
    let mut weights = Vec::new();
    let mut states = Vec::new();
    let mut rngs = Vec::new();
    let mut grads = Vec::new();
    for (i, shape) in shapes.iter().enumerate() {
        let (m, n) = (shape[0], shape[1]);
        weights.push(rng.gaussian_tensor(shape, 0.5));
        grads.push(rng.gaussian_tensor(shape, 1.0));
        states.push(match i % 6 {
            0 | 1 => state("mlorc_adamw", m, n, l),
            2 => state("mlorc_lion", m, n, l),
            3 => state("galore", m, n, l),
            4 => state("ldadamw", m, n, l),
            _ => state("adamw", m, n, l),
        });
        // each parameter owns an independent Omega stream
        rngs.push(rng.split(100 + i as u64));
    }
    (Fleet { weights, states, rngs }, grads)
}

fn run_rounds(fleet: &mut Fleet, grads: &[Tensor], workspaces: &mut [Workspace], rounds: usize) {
    for t in 1..=rounds {
        let mut jobs: Vec<HostStepJob> = fleet
            .weights
            .iter_mut()
            .zip(fleet.states.iter_mut())
            .zip(fleet.rngs.iter_mut())
            .zip(grads.iter())
            .map(|(((w, state), rng), g)| HostStepJob { w, grad: g, state, rng, lr: 1e-2, t })
            .collect();
        host_step_all(&mut jobs, workspaces).unwrap();
    }
}

#[test]
fn parallel_equals_sequential_bit_for_bit() {
    let (mut seq, grads) = fleet(7);
    let (mut par, grads2) = fleet(7);
    assert_eq!(grads.len(), grads2.len());

    let mut one_ws = vec![Workspace::new()];
    let mut many_ws: Vec<Workspace> = (0..4).map(|_| Workspace::new()).collect();
    run_rounds(&mut seq, &grads, &mut one_ws, 5);
    run_rounds(&mut par, &grads, &mut many_ws, 5);

    for (i, (a, b)) in seq.weights.iter().zip(&par.weights).enumerate() {
        assert_eq!(a.data, b.data, "weight {i} diverged between schedules");
    }
    for (i, (a, b)) in seq.states.iter().zip(&par.states).enumerate() {
        let (fa, fb) = (a.first_moment(), b.first_moment());
        match (fa, fb) {
            (Some(x), Some(y)) => assert_eq!(x.data, y.data, "state {i} first moment diverged"),
            (None, None) => {}
            _ => panic!("state {i} variant mismatch"),
        }
    }
}

#[test]
fn rerun_is_deterministic() {
    // Same seed, same schedule -> identical trajectories (RNG streams are
    // per-parameter, so this also pins the stream-splitting scheme).
    let (mut a, grads) = fleet(11);
    let (mut b, _) = fleet(11);
    let mut ws_a: Vec<Workspace> = (0..3).map(|_| Workspace::new()).collect();
    let mut ws_b: Vec<Workspace> = (0..3).map(|_| Workspace::new()).collect();
    run_rounds(&mut a, &grads, &mut ws_a, 3);
    run_rounds(&mut b, &grads, &mut ws_b, 3);
    for (x, y) in a.weights.iter().zip(&b.weights) {
        assert_eq!(x.data, y.data);
    }
}

#[test]
fn fused_applies_bit_identical_across_budgets() {
    // Both fused reconstruction+apply kernels (AdamW and Lion) through the
    // worker pool must produce the same bits for every band count —
    // emulating MLORC_THREADS ∈ {1, 2, 3, 8} via the per-thread override —
    // and inside a nested threads::serial scope. (512, 128, l=4) sizes the
    // applies and the factored-path GEMMs past the banding threshold.
    let (m, n, l) = (512usize, 128usize, 4usize);
    let hp = OptHp::mlorc_adamw();
    let hp_lion = OptHp::lion();
    let run = |budget: usize| {
        threads::with_budget(budget, || {
            let mut rng = Rng::new(42);
            let mut w = rng.gaussian_tensor(&[m, n], 0.5);
            let mut st = MlorcAdamWState::new(&[m, n], l);
            let mut wl = rng.gaussian_tensor(&[m, n], 0.5);
            let mut stl = MlorcLionState::new(&[m, n], l);
            let mut om_rng = Rng::new(7);
            for _ in 0..2 {
                let g = rng.gaussian_tensor(&[m, n], 1.0);
                st.step(&mut w, &g, 1e-2, &hp, &mut om_rng);
                stl.step(&mut wl, &g, 1e-2, &hp_lion, &mut om_rng);
            }
            (w, wl)
        })
    };
    let (w1, wl1) = run(1);
    for budget in [2usize, 3, 8] {
        let (w, wl) = run(budget);
        assert_eq!(w.data, w1.data, "fused adamw apply diverged at budget {budget}");
        assert_eq!(wl.data, wl1.data, "fused lion apply diverged at budget {budget}");
    }
    let (ws, wls) = threads::serial(|| run(8));
    assert_eq!(ws.data, w1.data, "fused adamw apply diverged inside serial scope");
    assert_eq!(wls.data, wl1.data, "fused lion apply diverged inside serial scope");
}

#[test]
fn galore_host_step_matches_reference() {
    // OptState::host_step must reproduce the reference GaloreState
    // trajectory bit-for-bit: both route through galore_refresh_projector
    // + galore_core with the same Omega stream; the trainer mirrors the
    // refresh cadence by clearing `refreshed` every update_freq steps.
    let hp = OptHp::adamw();
    let (l, freq) = (3usize, 2usize);
    for shape in [[10usize, 24], [24usize, 10]] {
        let (m, n) = (shape[0], shape[1]);
        let mut data_rng = Rng::new(5);
        let mut w_ref = data_rng.gaussian_tensor(&shape, 0.5);
        let mut w_host = w_ref.clone();
        let mut ref_st = GaloreState::new(&shape, l, freq);
        let mut host_st = state("galore", m, n, l);
        let mut rng_ref = Rng::new(11);
        let mut rng_host = Rng::new(11);
        let mut ws = Workspace::new();
        for step in 0..5 {
            let g = data_rng.gaussian_tensor(&shape, 1.0);
            ref_st.step(&mut w_ref, &g, 1e-2, &hp, &mut rng_ref);
            if step % freq == 0 {
                host_st.invalidate_projector();
            }
            host_st
                .host_step(&mut w_host, &g, 1e-2, step + 1, &mut rng_host, &mut ws)
                .unwrap();
            assert_eq!(w_ref.data, w_host.data, "galore {shape:?} step {step}");
        }
    }
}

#[test]
fn ldadamw_host_step_matches_reference() {
    // Same cross-validation for LDAdamW: one ldadamw_core, two drivers.
    // (The reference seeds P with identity columns, host state with zeros;
    // both are annihilated by the zero moments in step 1, so trajectories
    // coincide from the first step.)
    let hp = OptHp::adamw();
    let l = 3usize;
    for shape in [[8usize, 20], [20usize, 8]] {
        let (m, n) = (shape[0], shape[1]);
        let mut data_rng = Rng::new(6);
        let mut w_ref = data_rng.gaussian_tensor(&shape, 0.5);
        let mut w_host = w_ref.clone();
        let mut ref_st = LdAdamWState::new(&shape, l);
        let mut host_st = state("ldadamw", m, n, l);
        let mut rng_ref = Rng::new(13);
        let mut rng_host = Rng::new(13);
        let mut ws = Workspace::new();
        for step in 0..4 {
            let g = data_rng.gaussian_tensor(&shape, 1.0);
            ref_st.step(&mut w_ref, &g, 1e-2, &hp, &mut rng_ref);
            host_st
                .host_step(&mut w_host, &g, 1e-2, step + 1, &mut rng_host, &mut ws)
                .unwrap();
            assert_eq!(w_ref.data, w_host.data, "ldadamw {shape:?} step {step}");
        }
    }
}

#[test]
fn frozen_params_do_not_move() {
    let mut w = Tensor::full(&[4, 4], 1.0);
    let before = w.clone();
    let mut st = OptState::Frozen;
    let mut rng = Rng::new(0);
    let mut ws = vec![Workspace::new()];
    let grad = Tensor::full(&[4, 4], 5.0);
    let mut jobs = vec![HostStepJob {
        w: &mut w,
        grad: &grad,
        state: &mut st,
        rng: &mut rng,
        lr: 1.0,
        t: 1,
    }];
    host_step_all(&mut jobs, &mut ws).unwrap();
    assert_eq!(w.data, before.data);
}

#[test]
fn batched_planner_matches_sequential_for_every_method() {
    // The shape-class planner (host_step_all) must be bit-identical to
    // stepping each parameter sequentially through OptState::host_step,
    // for EVERY registered method — stacked QB kernels, quantized and
    // adaptive-rank routes, and the per-member fallback alike — across
    // thread budgets and several workspaces, with mixed class sizes:
    // three [24, 10] members share one class while the transposed
    // [10, 24] forms a class of size 1. Weights, every f32 state field
    // and every quantized code plane must agree to the bit.
    let shapes: [[usize; 2]; 4] = [[24, 10], [24, 10], [10, 24], [24, 10]];
    let (l, rank_min) = (4usize, 2usize);
    let build = |method: Method| {
        let mut rng = Rng::new(77);
        let mut weights = Vec::new();
        let mut states = Vec::new();
        let mut rngs = Vec::new();
        let mut grads = Vec::new();
        for (i, shape) in shapes.iter().enumerate() {
            let spec = ParamSpec {
                name: format!("p{i}"),
                shape: shape.to_vec(),
                kind: "matrix".into(),
                compressed: true,
            };
            weights.push(rng.gaussian_tensor(shape, 0.5));
            grads.push(rng.gaussian_tensor(shape, 1.0));
            states.push(OptState::for_param_cfg(method, &spec, l, rank_min).unwrap());
            rngs.push(rng.split(200 + i as u64));
        }
        (Fleet { weights, states, rngs }, grads)
    };
    for &method in Method::all() {
        if method.is_lora() {
            continue; // adapter methods need the graph engine's LoRA fleet
        }
        // Sequential oracle: one parameter at a time, in job order.
        let (mut seq, grads) = build(method);
        let mut ws = Workspace::new();
        for t in 1..=3 {
            for i in 0..seq.weights.len() {
                seq.states[i]
                    .host_step(&mut seq.weights[i], &grads[i], 1e-2, t, &mut seq.rngs[i], &mut ws)
                    .unwrap();
            }
        }
        for budget in [1usize, 2, 3, 8] {
            let (mut par, grads2) = build(method);
            threads::with_budget(budget, || {
                let mut workspaces: Vec<Workspace> = (0..3).map(|_| Workspace::new()).collect();
                run_rounds(&mut par, &grads2, &mut workspaces, 3);
            });
            for (i, (a, b)) in seq.weights.iter().zip(&par.weights).enumerate() {
                assert_eq!(a.data, b.data, "{method:?} budget {budget}: weight {i} diverged");
            }
            for (i, (a, b)) in seq.states.iter().zip(&par.states).enumerate() {
                let (fa, fb) = (a.tensor_fields(), b.tensor_fields());
                assert_eq!(fa.len(), fb.len(), "{method:?} budget {budget}: state {i} layout");
                for ((na, ta), (nb, tb)) in fa.iter().zip(&fb) {
                    assert_eq!(na, nb, "{method:?} budget {budget}: state {i} field order");
                    assert_eq!(
                        ta.data, tb.data,
                        "{method:?} budget {budget}: state {i} field {na} diverged"
                    );
                }
                for ((na, ta), (nb, tb)) in a.u8_fields().iter().zip(&b.u8_fields()) {
                    assert_eq!(na, nb, "{method:?} budget {budget}: state {i} u8 field order");
                    assert_eq!(
                        ta.data, tb.data,
                        "{method:?} budget {budget}: state {i} u8 field {na} diverged"
                    );
                }
            }
        }
    }
}
