//! Observability contract pins (PR 8): instrumentation must be invisible
//! to numerics — a training run with metrics/spans enabled is bitwise
//! identical to one with them disabled — and the shape-class batched
//! stepping path must account GEMM flops exactly like the per-parameter
//! path it replaced (same total madds, same multiset of recorded GEMMs).
//!
//! The obs gate is process-global, so every test that flips it
//! serializes on [`GATE`] and restores the enabled state before
//! releasing it.

use std::sync::Mutex;

use mlorc::config::{Method, RunConfig, TaskKind};
use mlorc::coordinator::{host_step_all, HostStepJob, OptState};
use mlorc::linalg::{flops, threads, Rng, Workspace};
use mlorc::obs;
use mlorc::serve::HostTrainer;
use mlorc::tensor::Tensor;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// One deterministic host training run; returns the final weights.
fn run_host(steps: usize, obs_enabled: bool) -> Vec<Tensor> {
    obs::force_enabled(obs_enabled);
    let mut cfg = RunConfig::new("host-nano", Method::MlorcAdamW, TaskKind::MathChain, steps);
    cfg.peak_lr = 0.03;
    cfg.log_every = 0;
    cfg.seed = 11;
    let mut tr = HostTrainer::new(cfg).unwrap();
    for _ in 0..steps {
        tr.train_step().unwrap();
    }
    tr.params.values.clone()
}

/// The <2%-overhead contract's harder half: zero *numeric* effect.
/// Counters, spans and snapshots may observe the step pipeline, but the
/// weights a run produces must not depend on whether they do.
#[test]
fn obs_on_and_off_runs_are_bit_identical() {
    let _g = gate();
    let on = run_host(8, true);
    let off = run_host(8, false);
    obs::force_enabled(true);
    assert_eq!(on.len(), off.len());
    for (i, (a, b)) in on.iter().zip(&off).enumerate() {
        assert_eq!(a.data, b.data, "param {i} differs between obs-on and obs-off runs");
    }
}

const FLEET: usize = 6;
const SHAPE: (usize, usize, usize) = (96, 40, 4);

/// Fresh mlorc_adamw fleet; both schedules call this with the same
/// constants so weights, states and Omega streams start identical.
fn fleet() -> Vec<(Tensor, OptState, Rng)> {
    let (m, n, r) = SHAPE;
    let mut seeder = Rng::new(77);
    (0..FLEET)
        .map(|i| {
            let mut rng = seeder.split(300 + i as u64);
            let w = rng.gaussian_tensor(&[m, n], 0.5);
            let state = OptState::for_variant("mlorc_adamw", &[m, n], r).unwrap();
            (w, state, rng)
        })
        .collect()
}

/// Flop-accounting parity (PR-8 satellite): the class-batched kernels
/// (`matmul_class_into`, `matmul_class_at_b_into`, `mgs_qr_class`, the
/// fused class apply) must record the same GEMMs as the per-parameter
/// path — equal madds totals AND an equal multiset of (op, dims)
/// records, so `gemm.madds` in a metrics snapshot means the same thing
/// whichever path the scheduler routed through.
#[test]
fn batched_class_step_accounts_flops_identically_to_per_param() {
    let _g = gate();
    obs::force_enabled(true);
    let (m, n, _) = SHAPE;
    let mut grad_rng = Rng::new(88);
    let grads: Vec<Tensor> = (0..FLEET).map(|_| grad_rng.gaussian_tensor(&[m, n], 1.0)).collect();

    // Per-parameter schedule: warm one step (factors leave zero), then
    // record step 2 on the calling thread.
    let mut fleet_seq = fleet();
    let mut ws = Workspace::new();
    for ((w, state, rng), g) in fleet_seq.iter_mut().zip(&grads) {
        state.host_step(w, g, 1e-3, 1, rng, &mut ws).unwrap();
    }
    flops::start_recording();
    for ((w, state, rng), g) in fleet_seq.iter_mut().zip(&grads) {
        state.host_step(w, g, 1e-3, 2, rng, &mut ws).unwrap();
    }
    let seq = flops::finish_recording();

    // Shape-class batched schedule over an identical fleet. The class
    // kernels record at entry on the calling thread, so the audit log
    // sees every member even when the work itself runs on the pool.
    let nws = threads::budget().max(1);
    let mut workspaces: Vec<Workspace> = (0..nws).map(|_| Workspace::new()).collect();
    let mut fleet_cls = fleet();
    for t in 1..=2usize {
        if t == 2 {
            flops::start_recording();
        }
        let mut jobs: Vec<HostStepJob> = fleet_cls
            .iter_mut()
            .zip(&grads)
            .map(|((w, state, rng), g)| HostStepJob { w, grad: g, state, rng, lr: 1e-3, t })
            .collect();
        host_step_all(&mut jobs, &mut workspaces).unwrap();
    }
    let bat = flops::finish_recording();

    assert_eq!(
        flops::total_madds(&seq),
        flops::total_madds(&bat),
        "batched madds total must equal per-parameter\nseq: {seq:?}\nbat: {bat:?}"
    );
    let key = |r: &flops::GemmRecord| (r.op, r.out_rows, r.inner, r.out_cols);
    let mut a: Vec<_> = seq.iter().map(key).collect();
    let mut b: Vec<_> = bat.iter().map(key).collect();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "batched GEMM record multiset must equal per-parameter");

    // and the schedules remain bit-identical (flop parity is not bought
    // with a different algorithm)
    for (i, ((wa, _, _), (wb, _, _))) in fleet_seq.iter().zip(&fleet_cls).enumerate() {
        assert_eq!(wa.data, wb.data, "param {i}: batched weights differ from per-parameter");
    }
}
